//! `intdecomp` — leader entrypoint and CLI.
//!
//! Subcommands:
//!   decompose                 compress one instance end-to-end (greedy vs BBO)
//!   run                       single BBO run, full trace to stdout/CSV
//!   compress-model            compress all layers of a synthetic model
//!                             concurrently (the parallel batched engine)
//!   shard plan|work|merge     cross-process sharded compress-model with
//!                             checkpoint/resume (one worker per process)
//!   serve                     long-lived compression daemon (line-delimited
//!                             JSON over TCP/Unix socket, admission control,
//!                             cross-request evaluation cache)
//!   serve-request             client for a running daemon (compress /
//!                             stats / ping / shutdown)
//!   brute-force               exact search of an instance
//!   greedy                    original SPADE baseline
//!   bench                     hot-path micro-benchmarks; --json writes
//!                             BENCH_<label>.json at the repo root;
//!                             --check FILE validates a snapshot's schema
//!   exp fig1|fig2|fig3|fig4|fig5|fig6|fig7|table1|table2|all
//!   artifacts-check           verify the PJRT artifacts against native math
//!
//! Common flags: --full (paper scale), --runs N, --iters N, --instances N,
//! --seed S, --n/--d/--k (problem shape), --solver sa|sqa|sq, --algo NAME,
//! --augment, --no-xla, --out DIR, --layers N (compress-model),
//! --workers N, --restart-workers N (Ising-restart fan-out),
//! --batch-size K (batched acquisition: candidates per surrogate fit),
//! --cache-key raw|canonical (evaluation-cache key policy).

use anyhow::{anyhow, bail, Result};

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig, WarmStart};
use intdecomp::bruteforce::brute_force;
use intdecomp::cli::Args;
use intdecomp::config::ExpConfig;
use intdecomp::cost::BinMatrix;
use intdecomp::engine::{self, Engine};
use intdecomp::experiments::{self as exp, Ctx};
use intdecomp::greedy::greedy;
use intdecomp::instance::generate;
use intdecomp::report::fmt;
use intdecomp::runtime::XlaRuntime;
use intdecomp::serve;
use intdecomp::shard;
use intdecomp::solvers;
use intdecomp::util::rng::Rng;

use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "decompose" => cmd_decompose(args),
        "run" => cmd_run(args),
        "compress-model" => cmd_compress_model(args),
        "shard" => cmd_shard(args),
        "serve" => cmd_serve(args),
        "serve-request" => cmd_serve_request(args),
        "brute-force" | "bruteforce" => cmd_brute_force(args),
        "greedy" => cmd_greedy(args),
        "bench" => cmd_bench(args),
        "exp" => cmd_exp(args),
        "artifacts-check" => cmd_artifacts_check(args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try: help)"),
    }
}

const HELP: &str = "\
intdecomp — lossy matrix compression by black-box optimisation of MINLP
(Kadowaki & Ambai, Sci Rep 2022 reproduction)

USAGE: intdecomp <subcommand> [flags]

  decompose        end-to-end compression of one instance (greedy vs BBO)
  run              one BBO run with trace output
  compress-model   compress every layer of a synthetic model concurrently
                   (the parallel batched engine; see --layers/--workers;
                   --report FILE writes the deterministic report)
  shard plan       partition a compress-model workload into shard
                   manifests (--shards S --dir D + the model flags);
                   the partition is shape-only, so any shard count
                   merges to identical results
  shard work       run one shard (--manifest F [--out LOG] [--workers N])
                   with crash-safe checkpoint/resume: each finished
                   layer is fsynced to a JSONL log, a restarted worker
                   skips completed layers and replays byte-identically
  shard merge      validate + combine shard logs (--dir D) into the
                   single-process report, byte for byte
                   (--report FILE, --csv FILE)
  serve            long-lived compression daemon: line-delimited JSON
                   requests over --addr HOST:PORT or --socket PATH,
                   bounded admission (--max-inflight / --max-per-client
                   / --admit-queue; excess gets an explicit 429 line),
                   a budgeted LRU cross-request evaluation cache
                   (--cache-budget[-bytes]), per-request deadlines and
                   disconnect cancellation, and a stats endpoint;
                   served reports are byte-identical to compress-model
  serve-request    client for a running daemon: --stats | --ping |
                   --jobs | --shutdown, or the compress-model flags to
                   submit a compression (--report FILE saves the
                   served deterministic report; --retry/--backoff-ms
                   retry refused connections and 429s)
  brute-force      exact search (best / second-best / solution orbit)
  greedy           the original SPADE baseline
  bench            hot-path micro-benchmarks (--quick, --json, --label L:
                   --json writes schema-checked BENCH_<L>.json at the
                   repo root — the tracked perf trajectory;
                   --check FILE validates an existing snapshot)
  exp <fig|table>  reproduce a paper figure/table:
                   fig1 fig2 fig3 fig4 fig5 fig6 fig7 table1 table2
                   ablation all
  artifacts-check  cross-check PJRT artifacts vs native math

FLAGS (defaults in parens):
  --full            paper scale (25 runs x 2n^2 iters x 10 instances)
  --runs N          BBO runs per algorithm/instance
  --iters N         acquisition iterations
  --instances N     number of synthetic instances
  --n/--d/--k       problem shape (8 / 100 / 3)
  --seed S          base seed (1)
  --algo NAME       rs|vbocs|nbocs|gbocs|fmqa08|fmqa12|rfmqa08 (nbocs)
  --solver NAME     sa|sqa|sq|exhaustive (sa)
  --augment         data augmentation (nBOCSa)
  --no-xla          skip PJRT artifacts, native math only
  --out DIR         results directory (results)
  --layers N        compress-model: number of layer matrices (4)
  --workers N       concurrent jobs / runs (all cores)
  --restart-workers N
                    Ising-restart fan-out per BBO iteration (1 = legacy
                    serial restarts; >1 = forked per-restart RNG streams,
                    bit-identical for any worker count)
  --batch-size K    batched acquisition: candidates acquired per
                    surrogate fit (1 = the paper's serial loop; K>1 =
                    one fit per K candidates, top-K distinct restart
                    minima evaluated concurrently — same evaluation
                    budget, ~K-fold fewer surrogate fits)
  --cache-key MODE  evaluation-cache keys: 'canonical' (default; folds
                    the K!*2^K symmetry orbit into one entry holding
                    the canonical representative's cost) or 'raw'
                    (exact keys, bit-identical to an uncached run)
  --report FILE     compress-model / shard merge: write the
                    deterministic per-layer report (no wall-clock
                    fields) — the byte-identity artifact CI diffs
  --save-state FILE compress-model: write each layer's final surrogate
                    state (one JSON document per line) for later
                    warm-started runs
  --warm-from FILE  compress-model: seed layer i's BBO from line i of
                    a --save-state file instead of the random init
                    design (rejected with a typed error on schema or
                    shape mismatch; omit for the bit-identical cold
                    path)
  --shards S        shard plan: number of shards (2)
  --dir D           shard plan/merge: plan directory (shards)
  --manifest FILE   shard work: the shard manifest to run
  --out LOG         shard work: result-log path (default: next to the
                    manifest, .results.jsonl).  NOTE: 'shard merge'
                    reads logs at the default location only — a log
                    written elsewhere (e.g. local scratch) must be
                    moved there before merging
  --addr HOST:PORT  serve / serve-request: TCP endpoint
                    (127.0.0.1:7341; port 0 binds a free port and
                    prints the actual one)
  --socket PATH     serve / serve-request: Unix-domain socket endpoint
                    (overrides --addr; Unix platforms only)
  --max-inflight N  serve: concurrent compress requests admitted
                    before the daemon queues or answers 429 (2)
  --max-per-client N
                    serve: per-client cap on held requests — running
                    plus queued; clients are keyed by peer IP on TCP
                    (0 = no per-client cap)
  --admit-queue N   serve: bounded admission wait queue; requests
                    beyond max-inflight wait here instead of bouncing,
                    overflow still gets 429 (0 = reject immediately)
  --cache-budget N  serve: cap on cross-request cache entries; the LRU
                    instance cache is evicted past it (0 disables the
                    shared cache; unset = unbounded)
  --cache-budget-bytes N
                    serve: same cap in estimated bytes
  --line-timeout-ms N
                    serve: a partially received request line older
                    than this is a 400 slow-loris rejection (10000;
                    0 = never)
  --state DIR       serve: optional state directory guarded by the
                    shard advisory lock (one daemon per directory);
                    with journaling on, requests and per-layer
                    progress are durable and a SIGKILL'd daemon
                    resumes on restart; per-instance surrogate states
                    are persisted under DIR/warm and warm-start later
                    requests on the same instance (the 'done' line
                    reports warm:true and its warm_source)
  --journal on|off  serve: write-ahead journaling of compress
                    requests under --state (on); off disables
                    durability but keeps the state lock
  --recover MODE    serve: bind-time recovery of journaled state —
                    'on' (default) finishes interrupted requests and
                    truncates torn bytes, 'off' skips the recovery
                    pass, 'strict' refuses to start on torn bytes
  --stats / --ping / --jobs / --shutdown
                    serve-request: send a control request instead of
                    a compression (--jobs lists journaled requests)
  --deadline-ms N   serve-request: per-request wall-time bound; the
                    daemon aborts past it with a 'deadline' line
  --retry N         serve-request: extra attempts after a refused
                    connection or a 429 response (0); the final
                    attempt's typed failure is preserved
  --backoff-ms B    serve-request: base retry backoff, doubled per
                    attempt plus a deterministic seeded jitter (100;
                    --retry-seed S reseeds the jitter stream)
";

/// Parse the loop-shaping flags shared by every BBO-running command
/// (`run`, `decompose`, `compress-model`, `shard`, `serve-request`) —
/// the one flag→config path (ISSUE 10), so a flag means the same thing
/// under every subcommand.
fn bbo_flag_overrides(args: &Args) -> Result<(bool, usize)> {
    let augment = args.bool_flag("augment");
    let restart_workers = args
        .usize_flag("restart-workers", 1)
        .map_err(|e| anyhow!(e))?;
    Ok((augment, restart_workers))
}

/// Assemble a run's [`BboConfig`] from parsed flags: the shared
/// builder chain over [`ExpConfig::bbo_config`] used by `run` and
/// `decompose` (the model-spec commands reach the same chain through
/// [`shard::ModelSpec::job`]).
fn bbo_config_from_args(
    args: &Args,
    cfg: &ExpConfig,
    n_bits: usize,
) -> Result<BboConfig> {
    let (augment, restart_workers) = bbo_flag_overrides(args)?;
    Ok(cfg
        .bbo_config(n_bits)
        .with_augment(augment)
        .with_restart_workers(restart_workers))
}

fn load_instance(args: &Args) -> Result<(ExpConfig, intdecomp::cost::Problem)> {
    let cfg = ExpConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let idx = args.usize_flag("instance", 1).map_err(|e| anyhow!(e))?;
    if idx < 1 {
        bail!("--instance is 1-based");
    }
    let p = generate(&cfg.instance, idx - 1);
    Ok((cfg, p))
}

fn cmd_decompose(args: &Args) -> Result<()> {
    let (cfg, p) = load_instance(args)?;
    println!(
        "instance: W {}x{}, K={}, n={} bits, compression ratio {:.3}",
        p.n(),
        p.d(),
        p.k,
        p.n_bits(),
        intdecomp::cost::compression_ratio(p.n(), p.d(), p.k, 32)
    );
    let g = greedy(&p, cfg.seed);
    println!(
        "greedy:    cost {}  (series {})  normalised error {:.4}",
        fmt(g.cost_refit),
        fmt(g.cost_series),
        p.normalised_error(g.cost_refit)
    );
    let bf = brute_force(&p);
    println!(
        "exact:     cost {}  second-best {}  orbit {}",
        fmt(bf.best_cost),
        fmt(bf.second_cost),
        bf.orbit.len()
    );
    let algo = Algorithm::by_name(&args.str_flag("algo", "nbocs"))
        .ok_or_else(|| anyhow!("unknown --algo"))?;
    let solver = solvers::by_name(&args.str_flag("solver", "sa"))
        .ok_or_else(|| anyhow!("unknown --solver"))?;
    let bcfg = bbo_config_from_args(args, &cfg, p.n_bits())?;
    let run = bbo::run(
        &p,
        &algo,
        solver.as_ref(),
        &bcfg,
        &Backends::default(),
        cfg.seed,
    );
    println!(
        "BBO {}: cost {} after {} evaluations in {:.2}s  (exact hit: {})",
        run.algo,
        fmt(run.best_y),
        run.ys.len(),
        run.time_total,
        run.found_exact(bf.best_cost, 1e-7)
    );
    let m = BinMatrix::from_spins(p.n(), p.k, &run.best_x);
    let c = p.solve_c(&m);
    println!(
        "M (binary, {}x{}) found; C is {}x{} real — residual {:.4} of ||W||",
        m.n,
        m.k,
        c.rows,
        c.cols,
        p.normalised_error(run.best_y)
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let (cfg, p) = load_instance(args)?;
    let algo = Algorithm::by_name(&args.str_flag("algo", "nbocs"))
        .ok_or_else(|| anyhow!("unknown --algo"))?;
    let solver = solvers::by_name(&args.str_flag("solver", "sa"))
        .ok_or_else(|| anyhow!("unknown --solver"))?;
    let bcfg = bbo_config_from_args(args, &cfg, p.n_bits())?;
    let run = bbo::run(
        &p,
        &algo,
        solver.as_ref(),
        &bcfg,
        &Backends::default(),
        cfg.seed,
    );
    println!("algo {}  solver {}  evals {}", run.algo, run.solver,
             run.ys.len());
    for (t, (y, b)) in
        run.ys.iter().zip(&run.best_curve).enumerate()
    {
        if t % 10 == 0 || t + 1 == run.ys.len() {
            println!("step {t:>5}  y {}  best {}", fmt(*y), fmt(*b));
        }
    }
    println!(
        "time: total {:.3}s  surrogate {:.3}s  solver {:.3}s  eval {:.3}s",
        run.time_total, run.time_surrogate, run.time_solver, run.time_eval
    );
    Ok(())
}

/// Build the canonical workload description from the CLI flags — the
/// same [`shard::ModelSpec`] the shard planner serialises, so a
/// single-process `compress-model` run and a sharded run construct
/// their jobs through one code path ([`shard::ModelSpec::job`]).
fn model_spec_from_args(args: &Args) -> Result<(shard::ModelSpec, ExpConfig)> {
    let cfg = ExpConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let layers = args.usize_flag("layers", 4).map_err(|e| anyhow!(e))?;
    let (augment, restart_workers) = bbo_flag_overrides(args)?;
    let spec = shard::ModelSpec {
        n: cfg.instance.n,
        d: cfg.instance.d,
        k: cfg.instance.k,
        gamma: cfg.instance.gamma,
        instance_seed: cfg.instance.seed,
        layers,
        iters: cfg.iters,
        restarts: cfg.restarts,
        batch_size: cfg.batch_size,
        augment,
        restart_workers,
        algo: args.str_flag("algo", "nbocs"),
        solver: args.str_flag("solver", "sa"),
        seed: cfg.seed,
        cache_key_raw: cfg.cache_key_raw,
    };
    spec.validate()?;
    Ok((spec, cfg))
}

/// Compress a whole synthetic model — one instance per layer — through the
/// parallel batched engine, and print the aggregated per-layer report.
fn cmd_compress_model(args: &Args) -> Result<()> {
    let (spec, cfg) = model_spec_from_args(args)?;
    let save_state = args.flags.get("save-state");
    let mut jobs = Vec::with_capacity(spec.layers);
    for i in 0..spec.layers {
        let mut job = spec.job(i)?;
        job.export_state = save_state.is_some();
        jobs.push(job);
    }
    // --warm-from FILE: one WarmStart JSON document per line, layer i
    // seeded from line i — the file a prior run's --save-state wrote.
    if let Some(path) = args.flags.get("warm-from") {
        let text = std::fs::read_to_string(path)?;
        let warms: Vec<WarmStart> = text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(WarmStart::parse)
            .collect::<std::result::Result<_, _>>()
            .map_err(|e| anyhow!("--warm-from {path}: {e}"))?;
        if warms.len() != jobs.len() {
            bail!(
                "--warm-from {path}: {} state lines for {} layers",
                warms.len(),
                jobs.len()
            );
        }
        for (job, warm) in jobs.iter_mut().zip(warms) {
            job.warm_start = Some(warm);
        }
    }

    println!(
        "compress-model: {} layers ({}x{}, K={}) on {} workers \
         (restart fan-out: {}, batch size: {})",
        spec.layers,
        spec.n,
        spec.d,
        spec.k,
        cfg.workers,
        spec.restart_workers,
        spec.batch_size
    );
    let t = intdecomp::util::timer::Timer::start();
    // The shared spec→engine path (ISSUE 10) — identical to the shard
    // worker's and the serve daemon's construction.
    let eng = Engine::new(spec.engine_config(cfg.workers, false));
    let results = eng.compress_all(jobs);
    let wall = t.seconds();

    let warm_layers = results.iter().filter(|r| r.warm).count();
    if warm_layers > 0 {
        println!("warm-started {warm_layers}/{} layers", results.len());
    }
    if let Some(path) = save_state {
        let mut out = String::new();
        for r in &results {
            let state = r.state.clone().ok_or_else(|| {
                anyhow!("layer '{}' exported no state", r.name)
            })?;
            let warm = WarmStart::new(state)
                .with_prev_best(r.run.best_x.clone(), r.run.best_y);
            out.push_str(&warm.to_string_strict().map_err(|e| {
                anyhow!("layer '{}' state not serialisable: {e}", r.name)
            })?);
            out.push('\n');
        }
        std::fs::write(path, out)?;
        println!("wrote {path} ({} layer states)", results.len());
    }

    print!("{}", engine::summary_table(&results));
    let (mut hits, mut lookups, mut evals) = (0u64, 0u64, 0usize);
    let mut serial_time = 0.0;
    for r in &results {
        hits += r.cache.hits;
        lookups += r.cache.lookups();
        evals += r.run.ys.len();
        serial_time += r.run.time_total;
    }
    println!(
        "total: {evals} evaluations, cache {hits}/{lookups} hits, \
         overall size {:.1}% of original",
        100.0 * engine::overall_ratio(&results)
    );
    println!(
        "wall {wall:.2}s vs per-job sum {serial_time:.2}s  \
         ({:.2}x concurrency)",
        serial_time / wall.max(1e-9)
    );
    let csv = std::path::Path::new(&cfg.out_dir).join("compress_model.csv");
    engine::write_results_csv(&csv, &results)?;
    println!("wrote {}", csv.display());
    if let Some(path) = args.flags.get("report") {
        let records: Vec<shard::LayerRecord> = results
            .iter()
            .enumerate()
            .map(|(i, r)| shard::LayerRecord::from_result(i, r))
            .collect();
        std::fs::write(path, shard::deterministic_report(&records))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Cross-process sharding: `shard plan | work | merge`.
fn cmd_shard(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("");
    match sub {
        "plan" => cmd_shard_plan(args),
        "work" => cmd_shard_work(args),
        "merge" => cmd_shard_merge(args),
        other => {
            bail!("unknown shard subcommand '{other}' (try: plan, work, merge)")
        }
    }
}

/// Partition a compress-model workload into shard manifests.
fn cmd_shard_plan(args: &Args) -> Result<()> {
    let (spec, _cfg) = model_spec_from_args(args)?;
    let shards = args.usize_flag("shards", 2).map_err(|e| anyhow!(e))?;
    let dir = PathBuf::from(args.str_flag("dir", "shards"));
    let paths = shard::write_plan(&spec, shards, &dir)?;
    println!(
        "planned {} layers into {shards} shards (fingerprint {})",
        spec.layers,
        spec.fingerprint()
    );
    for (jobs, path) in shard::partition(spec.layers, shards)
        .iter()
        .zip(&paths)
    {
        println!("  {} jobs -> {}", jobs.len(), path.display());
    }
    println!("run each shard:  intdecomp shard work --manifest <file>");
    println!(
        "then merge:      intdecomp shard merge --dir {}",
        dir.display()
    );
    Ok(())
}

/// Run one shard's jobs with checkpoint/resume.
fn cmd_shard_work(args: &Args) -> Result<()> {
    let manifest_path = args
        .flags
        .get("manifest")
        .ok_or_else(|| anyhow!("shard work requires --manifest <file>"))?;
    let manifest_path = Path::new(manifest_path);
    let manifest = shard::Manifest::load(manifest_path)?;
    let out = match args.flags.get("out") {
        Some(p) => PathBuf::from(p),
        None => shard::default_result_path(manifest_path),
    };
    let workers = args
        .usize_flag(
            "workers",
            intdecomp::util::threadpool::default_workers(),
        )
        .map_err(|e| anyhow!(e))?;
    println!(
        "shard {}/{}: {} jobs on {workers} workers, log {}",
        manifest.shard,
        manifest.shards,
        manifest.jobs.len(),
        out.display()
    );
    let t = intdecomp::util::timer::Timer::start();
    let run = shard::run_shard(&manifest, &out, workers, |rec| {
        let cost = fmt(rec.best_y);
        println!("  {}  cost {cost}  ({} evals)", rec.name, rec.evals);
    })?;
    println!(
        "shard {}/{} done in {:.2}s: {} jobs already complete (resumed), \
         {} ran, {} records at {}",
        manifest.shard,
        manifest.shards,
        t.seconds(),
        run.skipped,
        run.ran,
        run.records.len(),
        run.log_path.display()
    );
    Ok(())
}

/// Validate and merge every shard of a plan into the single-process
/// report (byte-identical to `compress-model --report`).
fn cmd_shard_merge(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_flag("dir", "shards"));
    let merged = shard::merge_dir(&dir)?;
    let report = shard::deterministic_report(&merged.records);
    print!("{report}");
    println!(
        "merged {} shards, {} layers (fingerprint {})",
        merged.shards,
        merged.records.len(),
        merged.spec.fingerprint()
    );
    if let Some(path) = args.flags.get("report") {
        std::fs::write(path, &report)?;
        println!("wrote {path}");
    }
    if let Some(path) = args.flags.get("csv") {
        shard::write_merged_csv(path, &merged.records)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Resolve the serve endpoint from `--socket` / `--addr`.
fn serve_endpoint(args: &Args) -> Result<serve::Endpoint> {
    if let Some(path) = args.flags.get("socket") {
        #[cfg(unix)]
        return Ok(serve::Endpoint::Unix(PathBuf::from(path)));
        #[cfg(not(unix))]
        bail!("--socket {path} needs a Unix platform; use --addr");
    }
    Ok(serve::Endpoint::Tcp(args.str_flag("addr", "127.0.0.1:7341")))
}

/// Run the long-lived compression daemon until a shutdown request.
fn cmd_serve(args: &Args) -> Result<()> {
    let parse_cap = |key: &str| -> Result<Option<usize>> {
        match args.flags.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse::<usize>().map_err(|_| {
                anyhow!("--{key} {v}: expected a non-negative integer")
            })?)),
        }
    };
    let cfg = serve::ServeConfig {
        endpoint: serve_endpoint(args)?,
        max_inflight: args
            .usize_flag("max-inflight", 2)
            .map_err(|e| anyhow!(e))?,
        max_per_client: args
            .usize_flag("max-per-client", 0)
            .map_err(|e| anyhow!(e))?,
        queue: args.usize_flag("admit-queue", 0).map_err(|e| anyhow!(e))?,
        workers: args
            .usize_flag(
                "workers",
                intdecomp::util::threadpool::default_workers(),
            )
            .map_err(|e| anyhow!(e))?,
        cache_budget: serve::CacheBudget {
            entries: parse_cap("cache-budget")?,
            bytes: parse_cap("cache-budget-bytes")?,
        },
        line_timeout_ms: args
            .u64_flag("line-timeout-ms", 10_000)
            .map_err(|e| anyhow!(e))?,
        state_dir: args.flags.get("state").map(PathBuf::from),
        journal: match args.str_flag("journal", "on").as_str() {
            "on" | "true" => true,
            "off" | "false" => false,
            v => bail!("--journal {v}: expected on|off"),
        },
        recover: serve::RecoverMode::parse(
            &args.str_flag("recover", "on"),
        )?,
    };
    let max_inflight = cfg.max_inflight;
    let server = serve::Server::bind(cfg)?;
    // The ready line: scripts parse the resolved endpoint from it
    // (important with --addr host:0), so flush before blocking.
    println!("serve: listening on {}", server.local_endpoint());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    eprintln!("serve: admitting {max_inflight} concurrent requests");
    server.run()?;
    println!("serve: shut down");
    Ok(())
}

/// Retry policy for `serve-request`: up to `retries` extra attempts
/// on connection-refused and `429` responses, sleeping an exponential
/// backoff (`backoff_ms << attempt`) plus a deterministic seeded
/// jitter between attempts.  Any other failure — and the final
/// attempt's — keeps its typed nonzero exit.
fn serve_request_with_retry(
    endpoint: &serve::Endpoint,
    line: &str,
    retries: usize,
    backoff_ms: u64,
    seed: u64,
) -> Result<Vec<String>> {
    use intdecomp::util::json::Json;
    use intdecomp::util::rng::Rng;

    let mut rng = Rng::new(seed);
    let mut attempt = 0usize;
    loop {
        let retryable_err;
        match serve::request(endpoint, line) {
            Ok(lines) => {
                let is_429 = lines.last().and_then(|l| Json::parse(l).ok())
                    .is_some_and(|j| {
                        j.get("type").and_then(Json::as_str)
                            == Some("error")
                            && j.get("code").and_then(Json::as_u64)
                                == Some(429)
                    });
                if !is_429 || attempt >= retries {
                    return Ok(lines);
                }
                retryable_err = "server at capacity (429)".to_string();
            }
            Err(e) => {
                let refused = e
                    .downcast_ref::<std::io::Error>()
                    .is_some_and(|io| {
                        io.kind()
                            == std::io::ErrorKind::ConnectionRefused
                    });
                if !refused || attempt >= retries {
                    return Err(e);
                }
                retryable_err = format!("{e:#}");
            }
        }
        // Exponential base with a seeded jitter in [0, base/2]: spreads
        // simultaneous retriers without losing reproducibility.
        let base = backoff_ms.saturating_mul(1u64 << attempt.min(16));
        let jitter = match base / 2 {
            0 => 0,
            half => rng.next_u64() % (half + 1),
        };
        let delay = base.saturating_add(jitter);
        eprintln!(
            "serve-request: attempt {} failed ({retryable_err}); \
             retrying in {delay} ms",
            attempt + 1
        );
        std::thread::sleep(std::time::Duration::from_millis(delay));
        attempt += 1;
    }
}

/// Send one request to a running daemon and print the response lines.
fn cmd_serve_request(args: &Args) -> Result<()> {
    use intdecomp::util::json::Json;

    let endpoint = serve_endpoint(args)?;
    let line = if args.bool_flag("stats") {
        serve::bare_request("stats")
    } else if args.bool_flag("ping") {
        serve::bare_request("ping")
    } else if args.bool_flag("jobs") {
        serve::bare_request("jobs")
    } else if args.bool_flag("shutdown") {
        serve::bare_request("shutdown")
    } else {
        let (spec, _cfg) = model_spec_from_args(args)?;
        match args.flags.get("deadline-ms") {
            Some(v) => {
                let ms = v.parse::<u64>().map_err(|_| {
                    anyhow!("--deadline-ms {v}: expected a u64")
                })?;
                serve::compress_request_with_deadline(&spec, ms)
            }
            None => serve::compress_request(&spec),
        }
    };
    let retries = args.usize_flag("retry", 0).map_err(|e| anyhow!(e))?;
    let backoff_ms =
        args.u64_flag("backoff-ms", 100).map_err(|e| anyhow!(e))?;
    let seed = args.u64_flag("retry-seed", 0x7341).map_err(|e| anyhow!(e))?;
    let lines = serve_request_with_retry(
        &endpoint, &line, retries, backoff_ms, seed,
    )?;
    for l in &lines {
        println!("{l}");
    }
    let last = lines.last().expect("request returns >= 1 line");
    let j = Json::parse(last).map_err(|e| anyhow!("response: {e}"))?;
    if j.get("type").and_then(Json::as_str) == Some("error") {
        let code = j.get("code").and_then(Json::as_u64).unwrap_or(0);
        let msg = j
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("unknown error");
        bail!("server error {code}: {msg}");
    }
    match j.get("type").and_then(Json::as_str) {
        Some(ty @ ("cancelled" | "deadline")) => {
            let done = j
                .get("layers_done")
                .and_then(Json::as_usize)
                .unwrap_or(0);
            bail!("request aborted ({ty}) after {done} layers");
        }
        _ => {}
    }
    if let Some(path) = args.flags.get("report") {
        let report = j
            .get("report")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("terminal line carries no report"))?;
        std::fs::write(path, report)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_brute_force(args: &Args) -> Result<()> {
    let (_cfg, p) = load_instance(args)?;
    let t = intdecomp::util::timer::Timer::start();
    let bf = brute_force(&p);
    println!(
        "evaluated {} canonical candidates in {:.2}s",
        bf.evaluated,
        t.seconds()
    );
    println!(
        "best cost {}  (normalised {:.4})  second-best {}",
        fmt(bf.best_cost),
        p.normalised_error(bf.best_cost),
        fmt(bf.second_cost)
    );
    println!(
        "canonical minimisers: {}   full orbit: {}",
        bf.canonical.len(),
        bf.orbit.len()
    );
    if args.bool_flag("gray") {
        let t = intdecomp::util::timer::Timer::start();
        let (best, _, evals) = intdecomp::bruteforce::full_scan_gray(&p);
        println!(
            "gray-code full scan: {} evals in {:.2}s, best {}",
            evals,
            t.seconds(),
            fmt(best)
        );
    }
    Ok(())
}

fn cmd_greedy(args: &Args) -> Result<()> {
    let (cfg, p) = load_instance(args)?;
    let t = intdecomp::util::timer::Timer::start();
    let g = greedy(&p, cfg.seed);
    println!(
        "greedy cost {} (series {}) in {:.5}s — normalised error {:.4}",
        fmt(g.cost_refit),
        fmt(g.cost_series),
        t.seconds(),
        p.normalised_error(g.cost_refit)
    );
    Ok(())
}

/// Hot-path micro-benchmarks on the in-tree harness: the numeric-core
/// kernels (blocked Cholesky / gram / posterior draw), the scratch-reusing
/// surrogate refit, dataset ingestion, the replica-engine solver
/// throughput rows (`solver/... sweeps ...`, reported as sweeps/sec) and
/// the batched BBO rows.  With `--json`, writes schema-validated
/// `BENCH_<label>.json` at the repo root — the same trajectory format
/// `cargo bench` emits (CI runs this as its bench smoke).
fn cmd_bench(args: &Args) -> Result<()> {
    use intdecomp::bench::{self, Bencher, BenchStats};
    use intdecomp::linalg::{cholesky_scaled, Matrix};
    use intdecomp::surrogate::{
        blr::{Blr, NativePosterior, PosteriorBackend, PosteriorScratch,
              Prior},
        Dataset, Surrogate,
    };

    // `--check FILE`: validate an existing snapshot's schema and exit —
    // CI runs this on every BENCH_*.json it is about to upload, so a
    // schema-invalid file fails the job instead of shipping garbage.
    if let Some(path) = args.flags.get("check") {
        let text = std::fs::read_to_string(path)?;
        let rows = bench::validate_json(&text)
            .map_err(|e| anyhow!("{path}: schema validation failed: {e}"))?;
        println!("{path}: schema ok ({rows} rows)");
        return Ok(());
    }

    let quick = args.bool_flag("quick");
    let label = args.str_flag("label", "local");
    let b = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 8) };
    let mut all: Vec<BenchStats> = Vec::new();
    let note = |s: BenchStats, all: &mut Vec<BenchStats>| {
        println!("{}", s.report());
        all.push(s);
    };
    let mut rng = Rng::new(99);
    let p = generate(&intdecomp::instance::InstanceConfig::default(), 0);
    let workers = intdecomp::util::threadpool::default_workers();

    // Numeric-core kernels at posterior scale (P = 301).
    let p_dim = 301;
    let a = Matrix::from_vec(320, p_dim, rng.normals(320 * p_dim));
    note(b.run("linalg/gram 320x301", 320, || a.gram().data[0]), &mut all);
    let g = {
        let mut g = a.gram();
        for i in 0..p_dim {
            g[(i, i)] += 5.0;
        }
        g
    };
    let lam = vec![1.0; p_dim];
    note(
        b.run("linalg/cholesky_scaled P=301", 1, || {
            cholesky_scaled(&g, 1.0, &lam, 0.0, 0.0)
                .map(|l| l[(0, 0)])
                .unwrap_or(0.0)
        }),
        &mut all,
    );
    let be = NativePosterior;
    let gv = rng.normals(p_dim);
    let z = rng.normals(p_dim);
    let mut scratch = PosteriorScratch::new();
    note(
        b.run("linalg/posterior draw (scratch reuse)", 1, || {
            be.draw_into(&g, &gv, &lam, 0.5, &z, &mut scratch)
                .expect("bench posterior is SPD")
        }),
        &mut all,
    );

    // Surrogate refit + dataset ingestion at paper scale.
    let mut data = Dataset::new(p.n_bits());
    for _ in 0..300 {
        let x = rng.spins(p.n_bits());
        let y = p.cost_spins(&x);
        data.push(x, y);
    }
    let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
    note(
        b.run("surrogate/nBOCS fit+draw", 1, || {
            blr.fit_model(&data, &mut rng)
                .expect("bench posterior is SPD")
                .energy(&[1i8; 24])
        }),
        &mut all,
    );
    note(
        b.run("surrogate/dataset push_batch k=8", 8, || {
            let mut d2 = data.clone();
            d2.push_batch((0..8).map(|_| (rng.spins(24), 0.5)));
            d2.len()
        }),
        &mut all,
    );
    // ISSUE 10: export→serialise→parse of a 300-row fitted state — the
    // cost a warm-store save/load pays per layer.
    note(
        b.run("surrogate/state roundtrip", 1, || {
            let state = intdecomp::bbo::SurrogateState {
                n_bits: p.n_bits(),
                dataset: data.clone(),
                surrogate: Some(blr.export_state()),
            };
            let text =
                state.to_string_strict().expect("finite bench state");
            intdecomp::bbo::SurrogateState::parse(&text)
                .expect("state roundtrips")
                .dataset
                .len()
        }),
        &mut all,
    );

    // Cost oracle, single and batched.
    let cands: Vec<intdecomp::cost::BinMatrix> = (0..256)
        .map(|_| {
            intdecomp::cost::BinMatrix::new(
                p.n(),
                p.k,
                rng.spins(p.n_bits()),
            )
        })
        .collect();
    note(
        b.run("cost/native x256", 256, || {
            cands.iter().map(|m| p.cost(m)).sum::<f64>()
        }),
        &mut all,
    );
    note(
        b.run("cost/native cost_batch x256", 256, || {
            p.cost_batch(&cands, workers).iter().sum::<f64>()
        }),
        &mut all,
    );

    // Replica-engine solver throughput (ISSUE 4): lockstep sweeps/sec
    // per algorithm and restart fan-out, plus the same-build per-chain
    // reference row (legacy execution model) at n = 64, r = 32.
    for n in [32usize, 64] {
        let m = solvers::QuadModel::random(n, &mut Rng::new(40 + n as u64));
        for name in ["sa", "sq", "sqa"] {
            let solver = solvers::by_name(name)
                .ok_or_else(|| anyhow!("unknown solver {name}"))?;
            let unit_sweeps = solver
                .lockstep_plan(&m, &m.stats())
                .expect("stochastic solvers have lockstep plans")
                .row_sweeps_per_unit();
            for restarts in [1usize, 8, 32] {
                let mut r = Rng::new(23);
                note(
                    b.run_sweeps(
                        &format!("solver/{name} sweeps n={n} r={restarts}"),
                        restarts,
                        unit_sweeps * restarts,
                        || {
                            solvers::solve_batch(
                                solver.as_ref(),
                                &m,
                                &mut r,
                                restarts,
                                1,
                                workers,
                            )[0]
                            .1
                        },
                    ),
                    &mut all,
                );
            }
            if n == 64 {
                let mut r = Rng::new(23);
                note(
                    b.run_sweeps(
                        &format!("solver/{name} sweeps n=64 r=32 per-chain"),
                        32,
                        unit_sweeps * 32,
                        || {
                            let streams: Vec<Rng> =
                                (0..32).map(|i| r.fork(i)).collect();
                            intdecomp::util::threadpool::parallel_map(
                                streams,
                                workers,
                                |mut c| {
                                    solvers::reference::solve_by_name(
                                        name, &m, &mut c,
                                    )
                                },
                            )
                            .len()
                        },
                    ),
                    &mut all,
                );
            }
        }
    }

    // The ISSUE 3 acceptance rows: batched BBO at a fixed eval budget.
    let evals = if quick { 16 } else { 48 };
    for batch in [1usize, 8] {
        let sa = solvers::sa::SimulatedAnnealing::default();
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), evals);
        cfg.batch_size = batch;
        let algo = Algorithm::Nbocs { sigma2: 0.1 };
        note(
            b.run(
                &format!("engine/bbo batch={batch} ({evals} evals)"),
                evals,
                || {
                    bbo::run(&p, &algo, &sa, &cfg, &Backends::default(), 5)
                        .best_y
                },
            ),
            &mut all,
        );
    }

    // ISSUE 10 acceptance row: the warm-started run at half the cold
    // budget (1 anchor + evals/2 - 1 acquisitions) — tracked against
    // the cold `engine/bbo batch=1` row above.
    {
        let sa = solvers::sa::SimulatedAnnealing::default();
        let algo = Algorithm::Nbocs { sigma2: 0.1 };
        let never = intdecomp::util::cancel::CancelToken::never();
        let donor = bbo::run_warm(
            &p,
            &algo,
            &sa,
            &BboConfig::smoke_scale(p.n_bits(), evals),
            &Backends::default(),
            5,
            &never,
            None,
            true,
        )
        .expect("bench donor run");
        let warm = WarmStart::new(
            donor.state.clone().expect("donor exports state"),
        )
        .with_prev_best(donor.run.best_x.clone(), donor.run.best_y);
        let warm_cfg =
            BboConfig::smoke_scale(p.n_bits(), evals / 2 - 1);
        note(
            b.run(
                &format!("bbo/warm-start speedup ({} evals)", evals / 2),
                evals / 2,
                || {
                    bbo::run_warm(
                        &p,
                        &algo,
                        &sa,
                        &warm_cfg,
                        &Backends::default(),
                        5,
                        &never,
                        Some(&warm),
                        false,
                    )
                    .expect("bench warm run")
                    .run
                    .best_y
                },
            ),
            &mut all,
        );
    }

    // Shard-subsystem overhead (ISSUE 5): planning cost at fleet scale
    // and the per-checkpoint JSONL record roundtrip — the fixed costs a
    // sharded run pays on top of the engine work.
    {
        let spec = shard::ModelSpec {
            n: 8,
            d: 100,
            k: 3,
            gamma: 0.7,
            instance_seed: 5005,
            layers: 1024,
            iters: 288,
            restarts: 10,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 1,
            cache_key_raw: false,
        };
        note(
            b.run("shard/plan 1024 layers x 16 shards", 16, || {
                shard::plan(&spec, 16).map(|m| m.len()).unwrap_or(0)
            }),
            &mut all,
        );
        let fp = spec.fingerprint();
        let rec = shard::LayerRecord {
            job: 3,
            name: "layer4".into(),
            n: 8,
            d: 100,
            k: 3,
            algo: "nBOCS".into(),
            solver: "sa".into(),
            evals: 1176,
            best_y: 0.031_257_194_7,
            best_x: vec![1, -1].repeat(12),
            err: 0.0417,
            ratio: 0.158_203_125,
            cache_hits: 40,
            cache_misses: 1136,
            surrogate_failures: 0,
            fallback_proposals: 0,
            rejected_costs: 0,
        };
        note(
            b.run("shard/record jsonl roundtrip x64", 64, || {
                let mut evals = 0usize;
                for _ in 0..64 {
                    let line =
                        rec.to_json_line(&fp).expect("finite record");
                    evals += shard::LayerRecord::parse_line(&line, &fp)
                        .expect("roundtrip")
                        .evals;
                }
                evals
            }),
            &mut all,
        );
    }

    // Serve-daemon round-trip latency (ISSUE 6): the p50/p99 columns of
    // bench schema v3 exist for these rows — wire/protocol overhead
    // (ping) and an end-to-end tiny compression against a live daemon
    // whose cross-request cache warms up over the reps.
    {
        use std::sync::Arc;
        let server = Arc::new(serve::Server::bind(serve::ServeConfig {
            endpoint: serve::Endpoint::Tcp("127.0.0.1:0".into()),
            max_inflight: 4,
            workers,
            ..Default::default()
        })?);
        let endpoint = server.local_endpoint().clone();
        let srv = Arc::clone(&server);
        let handle = std::thread::spawn(move || srv.run());
        note(
            b.run("serve/ping roundtrip", 1, || {
                serve::request(&endpoint, &serve::bare_request("ping"))
                    .map(|ls| ls.len())
                    .unwrap_or(0)
            }),
            &mut all,
        );
        let spec = shard::ModelSpec {
            n: 4,
            d: 8,
            k: 2,
            gamma: 0.8,
            instance_seed: 7,
            layers: 2,
            iters: if quick { 4 } else { 8 },
            restarts: 2,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 3,
            cache_key_raw: false,
        };
        let line = serve::compress_request(&spec);
        note(
            b.run("serve/compress 2-layer warm e2e", 2, || {
                serve::request(&endpoint, &line)
                    .map(|ls| ls.len())
                    .unwrap_or(0)
            }),
            &mut all,
        );
        // Deadline abort path (ISSUE 7): a ~0 ms deadline must come
        // back as a typed 'deadline' terminal line without touching
        // the engine — this row tracks the daemon's rejection latency.
        let dline = serve::compress_request_with_deadline(&spec, 1);
        note(
            b.run("serve/compress deadline_ms=1 abort", 1, || {
                serve::request(&endpoint, &dline)
                    .map(|ls| ls.len())
                    .unwrap_or(0)
            }),
            &mut all,
        );
        let _ = serve::request(&endpoint, &serve::bare_request("shutdown"));
        let _ = handle.join();
    }

    // Durability hot paths (ISSUE 8): the fsynced write-ahead journal
    // append a request pays before its first layer, and a full
    // bind-time recovery pass (journal scan + checkpoint replay of an
    // interrupted 1-layer request).
    {
        let dir = std::env::temp_dir().join("intdecomp_bench_journal");
        let spec = shard::ModelSpec {
            n: 4,
            d: 8,
            k: 2,
            gamma: 0.8,
            instance_seed: 7,
            layers: 1,
            iters: if quick { 2 } else { 4 },
            restarts: 2,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 3,
            cache_key_raw: false,
        };
        let fp = spec.fingerprint();
        note(
            b.run("serve/journal append x64", 64, || {
                let _ = std::fs::remove_dir_all(&dir);
                std::fs::create_dir_all(&dir).expect("bench tmpdir");
                let (mut j, _) =
                    serve::Journal::open(&serve::journal::journal_path(
                        &dir,
                    ))
                    .expect("journal open");
                for _ in 0..64usize {
                    j.record_admitted(&spec, &fp).expect("append");
                }
                64
            }),
            &mut all,
        );
        note(
            b.run("serve/recover replay", 1, || {
                let _ = std::fs::remove_dir_all(&dir);
                std::fs::create_dir_all(&dir).expect("bench tmpdir");
                {
                    let (mut j, _) = serve::Journal::open(
                        &serve::journal::journal_path(&dir),
                    )
                    .expect("journal open");
                    j.record_admitted(&spec, &fp).expect("append");
                }
                let server = serve::Server::bind(serve::ServeConfig {
                    endpoint: serve::Endpoint::Tcp(
                        "127.0.0.1:0".into(),
                    ),
                    workers,
                    state_dir: Some(dir.clone()),
                    ..Default::default()
                })
                .expect("recovery bind");
                server
                    .resume_stats()
                    .map(|r| r.replayed_layers as usize)
                    .unwrap_or(0)
            }),
            &mut all,
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Registry LRU churn (ISSUE 7): fill per-instance caches past an
    // entry budget and sweep — the cost of the daemon's post-request
    // `enforce()` under steady eviction pressure.
    {
        use intdecomp::cost::BinMatrix;
        let reg = serve::CacheRegistry::with_budget(serve::CacheBudget {
            entries: Some(64),
            bytes: None,
        });
        note(
            b.run("serve/registry lru churn x32", 32, || {
                let mut evicted = 0usize;
                for round in 0..32usize {
                    let cache = reg
                        .get(&format!("bench-l{}", round % 8))
                        .expect("budgeted registry");
                    for i in 0..16usize {
                        let spins: Vec<i8> = (0..16)
                            .map(|b| {
                                if ((round * 16 + i) >> b) & 1 == 1 {
                                    1
                                } else {
                                    -1
                                }
                            })
                            .collect();
                        let m = BinMatrix::new(16, 1, spins);
                        cache.get_or_eval(&m, |_| i as f64);
                    }
                    evicted += reg.enforce();
                }
                evicted
            }),
            &mut all,
        );
    }

    if args.bool_flag("json") {
        let path = bench::default_json_path(&label);
        bench::write_json(&path, &label, quick, &all)?;
        let text = std::fs::read_to_string(&path)?;
        let rows = bench::validate_json(&text)
            .map_err(|e| anyhow!("BENCH json failed validation: {e}"))?;
        println!("wrote {} ({rows} rows, schema ok)", path.display());
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let cfg = ExpConfig::from_args(args).map_err(|e| anyhow!(e))?;
    let ctx = Ctx::new(cfg);
    match which {
        "fig1" => exp::convergence::fig1(&ctx),
        "fig2" => exp::convergence::fig2(&ctx),
        "fig3" => exp::convergence::fig3(&ctx),
        "fig4" => exp::domains::fig4(&ctx),
        "fig5" => exp::solutions::fig5(&ctx),
        "fig6" => exp::hyper::fig6(&ctx),
        "fig7" => exp::convergence::fig7(&ctx),
        "table1" => exp::counts::table1(&ctx),
        "table2" => exp::timing::table2(&ctx),
        "ablation" => exp::ablation::ablation(&ctx),
        "all" => {
            exp::solutions::fig5(&ctx);
            exp::convergence::fig1(&ctx);
            exp::convergence::fig2(&ctx);
            exp::convergence::fig3(&ctx);
            exp::domains::fig4(&ctx);
            exp::hyper::fig6(&ctx);
            exp::convergence::fig7(&ctx);
            exp::counts::table1(&ctx);
            exp::timing::table2(&ctx);
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

/// Cross-check every artifact against the native twin on random inputs —
/// the from-rust integration gate (`make test` runs the equivalent via
/// `rust/tests/runtime_xla.rs`).
fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args.str_flag("artifacts", "artifacts");
    let rt = XlaRuntime::load(&dir)?;
    let meta = rt.meta.clone();
    println!("artifacts at {dir}: platform {}", rt.platform());
    let cfg = intdecomp::instance::InstanceConfig::default();
    let p = generate(&cfg, 0);
    let mut rng = Rng::new(7);

    // cost_batch vs native.
    let ms: Vec<BinMatrix> = (0..meta.batch + 3)
        .map(|_| BinMatrix::new(meta.n, meta.k, rng.spins(meta.n * meta.k)))
        .collect();
    let xla_costs = rt.cost_batch(&p.w, &ms)?;
    let mut max_err = 0.0f64;
    for (m, &xc) in ms.iter().zip(&xla_costs) {
        max_err = max_err.max((p.cost(m) - xc).abs());
    }
    println!(
        "cost_batch: {} candidates, max |native - xla| = {max_err:.2e}",
        ms.len()
    );
    if max_err > 1e-4 {
        bail!("cost artifact disagrees with native math");
    }

    // gram vs native.
    let mut data = intdecomp::surrogate::Dataset::new(meta.nbits);
    for _ in 0..50 {
        data.push(rng.spins(meta.nbits), rng.normal());
    }
    let phi = data.phi_matrix();
    let (g, gv, yty) = rt.gram(&phi, &data.ys)?;
    let mut gerr = 0.0f64;
    for (a, b) in g.data.iter().zip(&data.g.data) {
        gerr = gerr.max((a - b).abs());
    }
    for (a, b) in gv.iter().zip(&data.gv) {
        gerr = gerr.max((a - b).abs());
    }
    gerr = gerr.max((yty - data.yty).abs());
    println!("gram: max moment error = {gerr:.2e}");
    if gerr > 1e-2 {
        bail!("gram artifact disagrees with native math");
    }

    // bocs_sample vs native posterior.
    let lam = vec![1.0; meta.p];
    let z = vec![0.0; meta.p];
    let (alpha_x, _) = rt.bocs_draw(&data.g, &data.gv, &lam, 0.5, &z)?;
    use intdecomp::surrogate::blr::PosteriorBackend as _;
    let (alpha_n, _) = intdecomp::surrogate::blr::NativePosterior
        .draw(&data.g, &data.gv, &lam, 0.5, &z)?;
    let aerr = alpha_x
        .iter()
        .zip(&alpha_n)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("bocs_sample: max |native - xla| = {aerr:.2e}");
    if aerr > 1e-2 {
        bail!("bocs_sample artifact disagrees with native math");
    }

    println!("artifacts OK");
    Ok(())
}
