//! `cargo bench` — one bench group per paper table/figure plus the §Perf
//! hot-path microbenchmarks, on the in-tree harness (criterion is not
//! vendored; DESIGN.md §6).
//!
//! Groups:
//!   cost        — black-box evaluation: native vs XLA artifact (L1 path)
//!   bruteforce  — Table 2 "brute force" row workloads
//!   solvers     — Fig. 2 back-ends on a 24-spin surrogate
//!   surrogate   — per-iteration surrogate fits (Table 2 decomposition)
//!   bbo         — end-to-end iterations per algorithm (Tables 1/2 engine)
//!   engine      — restart fan-out vs the serial restart loop, batched
//!                 acquisition (batch_size 1 vs ≥4 at a fixed evaluation
//!                 budget on the paper-scale instance), and batched
//!                 multi-layer compression (workers 1 vs many)

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::bench::Bencher;
use intdecomp::bruteforce::{brute_force, full_scan_gray};
use intdecomp::cost::BinMatrix;
use intdecomp::engine::{CompressionJob, Engine};
use intdecomp::greedy::greedy;
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::runtime::XlaRuntime;
use intdecomp::solvers::{self};
use intdecomp::surrogate::{
    blr::{Blr, Prior},
    fm::FactorizationMachine,
    Dataset, Surrogate,
};
use intdecomp::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick {
        Bencher::new(1, 3)
    } else {
        Bencher::new(2, 8)
    };
    let p = generate(&InstanceConfig::default(), 0);
    let mut rng = Rng::new(99);

    println!("== cost: black-box evaluation (8x100, K=3) ==");
    let batch: Vec<BinMatrix> = (0..256)
        .map(|_| BinMatrix::new(p.n(), p.k, rng.spins(p.n_bits())))
        .collect();
    let s = b.run("cost/native x256", 256, || {
        batch.iter().map(|m| p.cost(m)).sum::<f64>()
    });
    println!("{}", s.report());
    if let Some(rt) = XlaRuntime::load_default() {
        let s = b.run("cost/xla-artifact x256", 256, || {
            rt.cost_batch(&p.w, &batch).unwrap().iter().sum::<f64>()
        });
        println!("{}", s.report());
    } else {
        println!("cost/xla-artifact: skipped (no artifacts/)");
    }

    println!("\n== bruteforce: exact search (Table 2 reference row) ==");
    let s = b.run("bruteforce/canonical 357760", 357_760, || {
        brute_force(&p).best_cost
    });
    println!("{}", s.report());
    if !quick {
        let small = generate(
            &InstanceConfig { n: 6, d: 40, k: 3, gamma: 0.7, seed: 5 },
            0,
        );
        let s = b.run("bruteforce/gray 2^18", 1 << 18, || {
            full_scan_gray(&small).0
        });
        println!("{}", s.report());
    }

    println!("\n== solvers: 24-spin surrogate minimisation (Fig. 2) ==");
    let model = {
        let mut data = Dataset::new(p.n_bits());
        for _ in 0..100 {
            let x = rng.spins(p.n_bits());
            let y = p.cost_spins(&x);
            data.push(x, y);
        }
        let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
        blr.fit_model(&data, &mut rng)
    };
    for name in ["sa", "sqa", "sq"] {
        let solver = solvers::by_name(name).unwrap();
        let mut r = Rng::new(7);
        let s = b.run(&format!("solver/{name} best-of-10"), 10, || {
            solver.solve_best(&model, &mut r, 10).1
        });
        println!("{}", s.report());
    }

    println!("\n== surrogate: per-iteration fit at paper scale (Table 2) ==");
    let mut data = Dataset::new(p.n_bits());
    let mut r2 = Rng::new(8);
    for _ in 0..300 {
        let x = r2.spins(p.n_bits());
        let y = p.cost_spins(&x);
        data.push(x, y);
    }
    for (label, prior) in [
        ("nBOCS", Prior::Normal { sigma2: 0.1 }),
        ("gBOCS", Prior::NormalGamma { a: 1.0, beta: 0.001 }),
        ("vBOCS", Prior::Horseshoe),
    ] {
        let mut blr = Blr::new(prior);
        let s = b.run(&format!("surrogate/{label} fit+draw"), 1, || {
            blr.fit_model(&data, &mut r2).energy(&vec![1i8; 24])
        });
        println!("{}", s.report());
    }
    {
        let mut fm = FactorizationMachine::new(p.n_bits(), 8, &mut r2);
        fm.steps = 200;
        let s = b.run("surrogate/FMQA08 train (200 adam)", 200, || {
            fm.fit_model(&data, &mut r2).energy(&vec![1i8; 24])
        });
        println!("{}", s.report());
    }
    {
        let s = b.run("surrogate/dataset push (rank-1 moments)", 1, || {
            let mut d2 = data.clone();
            d2.push(r2.spins(24), 0.5);
            d2.len()
        });
        println!("{}", s.report());
    }

    println!("\n== bbo: end-to-end iterations (Tables 1/2 engine) ==");
    let iters = if quick { 10 } else { 30 };
    for (label, algo) in [
        ("nBOCS", Algorithm::Nbocs { sigma2: 0.1 }),
        ("gBOCS", Algorithm::Gbocs { beta: 0.001 }),
        ("FMQA08", Algorithm::Fmqa { k_fm: 8 }),
        ("RS", Algorithm::Rs),
    ] {
        let sa = solvers::sa::SimulatedAnnealing::default();
        let cfg = BboConfig::smoke_scale(p.n_bits(), iters);
        let s = b.run(&format!("bbo/{label} {iters} iters"), iters, || {
            bbo::run(&p, &algo, &sa, &cfg, &Backends::default(), 3).best_y
        });
        println!("{}", s.report());
    }
    {
        let s = b.run("baseline/greedy (Table 2 row)", 1, || {
            greedy(&p, 1).cost_refit
        });
        println!("{}", s.report());
    }

    println!("\n== engine: restart fan-out + batched compression jobs ==");
    let workers = intdecomp::util::threadpool::default_workers();
    {
        // Same forked-stream semantics in both rows, so the only variable
        // is the thread fan-out; throughput is restarts/s.
        let sa = solvers::sa::SimulatedAnnealing::default();
        let mut r = Rng::new(17);
        let s = b.run("engine/restarts x10 serial", 10, || {
            solvers::solve_best_parallel(&sa, &model, &mut r, 10, 1).1
        });
        println!("{}", s.report());
        let s = b.run(
            &format!("engine/restarts x10 fan-out ({workers} workers)"),
            10,
            || solvers::solve_best_parallel(&sa, &model, &mut r, 10, workers).1,
        );
        println!("{}", s.report());
    }
    {
        // Batched acquisition on the paper-scale instance (8x100, K=3,
        // 24 bits): identical evaluation budget and identical (serial)
        // restart fan-out in every row, so the whole gap is batching
        // itself — amortised surrogate fits (one per batch instead of
        // one per evaluation) plus the concurrent candidate evaluation.
        let evals = if quick { 16 } else { 48 };
        for batch in [1usize, 4, 8] {
            let sa = solvers::sa::SimulatedAnnealing::default();
            let mut cfg = BboConfig::smoke_scale(p.n_bits(), evals);
            cfg.batch_size = batch;
            let algo = Algorithm::Nbocs { sigma2: 0.1 };
            let s = b.run(
                &format!("engine/bbo batch={batch} ({evals} evals)"),
                evals,
                || {
                    bbo::run(&p, &algo, &sa, &cfg, &Backends::default(), 5)
                        .best_y
                },
            );
            println!("{}", s.report());
        }
    }
    {
        let n_jobs = 4;
        let jiters = if quick { 6 } else { 15 };
        let make_jobs = || -> Vec<CompressionJob> {
            (0..n_jobs)
                .map(|i| {
                    let icfg = InstanceConfig {
                        n: 6,
                        d: 30,
                        k: 2,
                        gamma: 0.7,
                        seed: 11,
                    };
                    CompressionJob::new(
                        format!("layer{i}"),
                        generate(&icfg, i),
                        jiters,
                        1000 + i as u64,
                    )
                })
                .collect()
        };
        let s = b.run("engine/compress_all 4 jobs serial", n_jobs, || {
            Engine::with_workers(1).compress_all(make_jobs()).len()
        });
        println!("{}", s.report());
        let s = b.run(
            &format!(
                "engine/compress_all 4 jobs ({} workers)",
                workers.min(n_jobs)
            ),
            n_jobs,
            || {
                Engine::with_workers(workers.min(n_jobs))
                    .compress_all(make_jobs())
                    .len()
            },
        );
        println!("{}", s.report());
    }
}
