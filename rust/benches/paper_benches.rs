//! `cargo bench` — one bench group per paper table/figure plus the §Perf
//! hot-path microbenchmarks, on the in-tree harness (criterion is not
//! vendored; DESIGN.md §6).
//!
//! Groups:
//!   linalg      — blocked numeric-core kernels at posterior scale (P=301)
//!   cost        — black-box evaluation: native vs XLA artifact (L1 path)
//!   bruteforce  — Table 2 "brute force" row workloads
//!   solvers     — Fig. 2 back-ends on a 24-spin surrogate
//!   solver-throughput — replica-major lockstep engine sweeps/sec per
//!                 algorithm at n ∈ {32, 64}, restarts ∈ {1, 8, 32},
//!                 plus same-build per-chain reference rows (the legacy
//!                 execution model) at n = 64, r = 32 — the ISSUE 4
//!                 acceptance comparison lives inside one BENCH file
//!   surrogate   — per-iteration surrogate fits (Table 2 decomposition)
//!   bbo         — end-to-end iterations per algorithm (Tables 1/2 engine)
//!   engine      — restart fan-out vs the serial restart loop, batched
//!                 acquisition (batch_size 1 vs ≥4 at a fixed evaluation
//!                 budget on the paper-scale instance), and batched
//!                 multi-layer compression (workers 1 vs many)
//!   shard       — cross-process sharding overhead: fleet-scale plan
//!                 construction and the per-job JSONL checkpoint-record
//!                 roundtrip (ISSUE 5)
//!
//! Every run writes `BENCH_<label>.json` at the repo root
//! (`--label NAME`, default "local"; `--quick` for short iterations) so
//! the perf trajectory is tracked in-tree — see README "Benchmarks".

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::bench::{self, Bencher, BenchStats};
use intdecomp::bruteforce::{brute_force, full_scan_gray};
use intdecomp::cost::BinMatrix;
use intdecomp::engine::{CompressionJob, Engine};
use intdecomp::greedy::greedy;
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::linalg::{cholesky_scaled, Matrix};
use intdecomp::runtime::XlaRuntime;
use intdecomp::shard;
use intdecomp::solvers::{self};
use intdecomp::surrogate::{
    blr::{Blr, NativePosterior, PosteriorBackend, PosteriorScratch, Prior},
    fm::FactorizationMachine,
    Dataset, Surrogate,
};
use intdecomp::util::rng::Rng;

fn note(all: &mut Vec<BenchStats>, s: BenchStats) {
    println!("{}", s.report());
    all.push(s);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let label = argv
        .iter()
        .position(|a| a == "--label")
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .or_else(|| {
            argv.iter().find_map(|a| {
                a.strip_prefix("--label=").map(str::to_string)
            })
        })
        .unwrap_or_else(|| "local".into());
    let b = if quick {
        Bencher::new(1, 3)
    } else {
        Bencher::new(2, 8)
    };
    let mut all: Vec<BenchStats> = Vec::new();
    let p = generate(&InstanceConfig::default(), 0);
    let mut rng = Rng::new(99);
    let workers = intdecomp::util::threadpool::default_workers();

    println!("== linalg: blocked kernels at posterior scale (P = 301) ==");
    {
        let p_dim = 301;
        let a = Matrix::from_vec(320, p_dim, rng.normals(320 * p_dim));
        note(
            &mut all,
            b.run("linalg/gram 320x301", 320, || a.gram().data[0]),
        );
        let g = {
            let mut g = a.gram();
            for i in 0..p_dim {
                g[(i, i)] += 5.0;
            }
            g
        };
        let lam = vec![1.0; p_dim];
        note(
            &mut all,
            b.run("linalg/cholesky_scaled P=301", 1, || {
                cholesky_scaled(&g, 1.0, &lam, 0.0, 0.0)
                    .map(|l| l[(0, 0)])
                    .unwrap_or(0.0)
            }),
        );
        let be = NativePosterior;
        let gv = rng.normals(p_dim);
        let z = rng.normals(p_dim);
        let mut scratch = PosteriorScratch::new();
        note(
            &mut all,
            b.run("linalg/posterior draw (scratch reuse)", 1, || {
                be.draw_into(&g, &gv, &lam, 0.5, &z, &mut scratch)
                    .expect("bench posterior is SPD")
            }),
        );
        note(
            &mut all,
            b.run("linalg/posterior draw (fresh alloc)", 1, || {
                be.draw(&g, &gv, &lam, 0.5, &z)
                    .expect("bench posterior is SPD")
                    .1
            }),
        );
    }

    println!("\n== cost: black-box evaluation (8x100, K=3) ==");
    let batch: Vec<BinMatrix> = (0..256)
        .map(|_| BinMatrix::new(p.n(), p.k, rng.spins(p.n_bits())))
        .collect();
    note(
        &mut all,
        b.run("cost/native x256", 256, || {
            batch.iter().map(|m| p.cost(m)).sum::<f64>()
        }),
    );
    note(
        &mut all,
        b.run(
            &format!("cost/native cost_batch x256 ({workers} workers)"),
            256,
            || p.cost_batch(&batch, workers).iter().sum::<f64>(),
        ),
    );
    if let Some(rt) = XlaRuntime::load_default() {
        note(
            &mut all,
            b.run("cost/xla-artifact x256", 256, || {
                rt.cost_batch(&p.w, &batch).unwrap().iter().sum::<f64>()
            }),
        );
    } else {
        println!("cost/xla-artifact: skipped (no artifacts/)");
    }

    println!("\n== bruteforce: exact search (Table 2 reference row) ==");
    note(
        &mut all,
        b.run("bruteforce/canonical 357760", 357_760, || {
            brute_force(&p).best_cost
        }),
    );
    if !quick {
        let small = generate(
            &InstanceConfig { n: 6, d: 40, k: 3, gamma: 0.7, seed: 5 },
            0,
        );
        note(
            &mut all,
            b.run("bruteforce/gray 2^18", 1 << 18, || {
                full_scan_gray(&small).0
            }),
        );
    }

    println!("\n== solvers: 24-spin surrogate minimisation (Fig. 2) ==");
    let model = {
        let mut data = Dataset::new(p.n_bits());
        for _ in 0..100 {
            let x = rng.spins(p.n_bits());
            let y = p.cost_spins(&x);
            data.push(x, y);
        }
        let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
        blr.fit_model(&data, &mut rng).expect("bench posterior is SPD")
    };
    for name in ["sa", "sqa", "sq"] {
        let solver = solvers::by_name(name).unwrap();
        let mut r = Rng::new(7);
        note(
            &mut all,
            b.run(&format!("solver/{name} best-of-10"), 10, || {
                solver.solve_best(&model, &mut r, 10).1
            }),
        );
    }

    println!("\n== solver-throughput: replica-major lockstep engine ==");
    for n in [32usize, 64] {
        let m = solvers::QuadModel::random(n, &mut Rng::new(40 + n as u64));
        for name in ["sa", "sq", "sqa"] {
            let solver = solvers::by_name(name).unwrap();
            let unit_sweeps = solver
                .lockstep_plan(&m, &m.stats())
                .expect("stochastic solvers have lockstep plans")
                .row_sweeps_per_unit();
            for restarts in [1usize, 8, 32] {
                let mut r = Rng::new(23);
                note(
                    &mut all,
                    b.run_sweeps(
                        &format!("solver/{name} sweeps n={n} r={restarts}"),
                        restarts,
                        unit_sweeps * restarts,
                        || {
                            solvers::solve_batch(
                                solver.as_ref(),
                                &m,
                                &mut r,
                                restarts,
                                1,
                                workers,
                            )[0]
                            .1
                        },
                    ),
                );
            }
            if n == 64 {
                // Same forked streams and worker fan-out, legacy
                // per-chain execution (scalar chains, per-restart
                // schedule scans): the ISSUE 4 acceptance row compares
                // this against `solver/{name} sweeps n=64 r=32` above.
                let mut r = Rng::new(23);
                note(
                    &mut all,
                    b.run_sweeps(
                        &format!("solver/{name} sweeps n=64 r=32 per-chain"),
                        32,
                        unit_sweeps * 32,
                        || {
                            let streams: Vec<Rng> =
                                (0..32).map(|i| r.fork(i)).collect();
                            intdecomp::util::threadpool::parallel_map(
                                streams,
                                workers,
                                |mut c| {
                                    solvers::reference::solve_by_name(
                                        name, &m, &mut c,
                                    )
                                },
                            )
                            .len()
                        },
                    ),
                );
            }
        }
    }

    println!("\n== surrogate: per-iteration fit at paper scale (Table 2) ==");
    let mut data = Dataset::new(p.n_bits());
    let mut r2 = Rng::new(8);
    for _ in 0..300 {
        let x = r2.spins(p.n_bits());
        let y = p.cost_spins(&x);
        data.push(x, y);
    }
    for (label, prior) in [
        ("nBOCS", Prior::Normal { sigma2: 0.1 }),
        ("gBOCS", Prior::NormalGamma { a: 1.0, beta: 0.001 }),
        ("vBOCS", Prior::Horseshoe),
    ] {
        let mut blr = Blr::new(prior);
        note(
            &mut all,
            b.run(&format!("surrogate/{label} fit+draw"), 1, || {
                blr.fit_model(&data, &mut r2)
                    .expect("bench posterior is SPD")
                    .energy(&[1i8; 24])
            }),
        );
    }
    {
        let mut fm = FactorizationMachine::new(p.n_bits(), 8, &mut r2);
        fm.steps = 200;
        note(
            &mut all,
            b.run("surrogate/FMQA08 train (200 adam)", 200, || {
                fm.fit_model(&data, &mut r2)
                    .expect("bench fm stays finite")
                    .energy(&[1i8; 24])
            }),
        );
    }
    {
        note(
            &mut all,
            b.run("surrogate/dataset push (rank-1 moments)", 1, || {
                let mut d2 = data.clone();
                d2.push(r2.spins(24), 0.5);
                d2.len()
            }),
        );
        note(
            &mut all,
            b.run("surrogate/dataset push_batch k=8 (rank-k)", 8, || {
                let mut d2 = data.clone();
                d2.push_batch((0..8).map(|_| (r2.spins(24), 0.5)));
                d2.len()
            }),
        );
    }

    println!("\n== bbo: end-to-end iterations (Tables 1/2 engine) ==");
    let iters = if quick { 10 } else { 30 };
    for (label, algo) in [
        ("nBOCS", Algorithm::Nbocs { sigma2: 0.1 }),
        ("gBOCS", Algorithm::Gbocs { beta: 0.001 }),
        ("FMQA08", Algorithm::Fmqa { k_fm: 8 }),
        ("RS", Algorithm::Rs),
    ] {
        let sa = solvers::sa::SimulatedAnnealing::default();
        let cfg = BboConfig::smoke_scale(p.n_bits(), iters);
        note(
            &mut all,
            b.run(&format!("bbo/{label} {iters} iters"), iters, || {
                bbo::run(&p, &algo, &sa, &cfg, &Backends::default(), 3)
                    .best_y
            }),
        );
    }
    note(
        &mut all,
        b.run("baseline/greedy (Table 2 row)", 1, || {
            greedy(&p, 1).cost_refit
        }),
    );

    println!("\n== engine: restart fan-out + batched compression jobs ==");
    {
        // Same forked-stream semantics in both rows, so the only variable
        // is the thread fan-out; throughput is restarts/s.
        let sa = solvers::sa::SimulatedAnnealing::default();
        let mut r = Rng::new(17);
        note(
            &mut all,
            b.run("engine/restarts x10 serial", 10, || {
                solvers::solve_best_parallel(&sa, &model, &mut r, 10, 1).1
            }),
        );
        note(
            &mut all,
            b.run(
                &format!("engine/restarts x10 fan-out ({workers} workers)"),
                10,
                || {
                    solvers::solve_best_parallel(
                        &sa, &model, &mut r, 10, workers,
                    )
                    .1
                },
            ),
        );
    }
    {
        // Batched acquisition on the paper-scale instance (8x100, K=3,
        // 24 bits): identical evaluation budget and identical (serial)
        // restart fan-out in every row, so the whole gap is batching
        // itself — amortised surrogate fits (one per batch instead of
        // one per evaluation) plus the concurrent candidate evaluation.
        // This is the acceptance row of ISSUE 3 (`bbo batch=8`).
        let evals = if quick { 16 } else { 48 };
        for batch in [1usize, 4, 8] {
            let sa = solvers::sa::SimulatedAnnealing::default();
            let mut cfg = BboConfig::smoke_scale(p.n_bits(), evals);
            cfg.batch_size = batch;
            let algo = Algorithm::Nbocs { sigma2: 0.1 };
            note(
                &mut all,
                b.run(
                    &format!("engine/bbo batch={batch} ({evals} evals)"),
                    evals,
                    || {
                        bbo::run(
                            &p,
                            &algo,
                            &sa,
                            &cfg,
                            &Backends::default(),
                            5,
                        )
                        .best_y
                    },
                ),
            );
        }
    }
    {
        let n_jobs = 4;
        let jiters = if quick { 6 } else { 15 };
        let make_jobs = || -> Vec<CompressionJob> {
            (0..n_jobs)
                .map(|i| {
                    let icfg = InstanceConfig {
                        n: 6,
                        d: 30,
                        k: 2,
                        gamma: 0.7,
                        seed: 11,
                    };
                    CompressionJob::new(
                        format!("layer{i}"),
                        generate(&icfg, i),
                        jiters,
                        1000 + i as u64,
                    )
                })
                .collect()
        };
        note(
            &mut all,
            b.run("engine/compress_all 4 jobs serial", n_jobs, || {
                Engine::with_workers(1).compress_all(make_jobs()).len()
            }),
        );
        note(
            &mut all,
            b.run(
                &format!(
                    "engine/compress_all 4 jobs ({} workers)",
                    workers.min(n_jobs)
                ),
                n_jobs,
                || {
                    Engine::with_workers(workers.min(n_jobs))
                        .compress_all(make_jobs())
                        .len()
                },
            ),
        );
    }

    println!("\n== shard: cross-process planning + checkpoint overhead ==");
    {
        // The fixed costs a sharded run pays on top of the engine work:
        // planning a fleet-scale model into manifests, and the per-job
        // JSONL checkpoint record roundtrip (serialise + fsync-sized
        // line + parse) — see rust/src/shard.
        let spec = shard::ModelSpec {
            n: 8,
            d: 100,
            k: 3,
            gamma: 0.7,
            instance_seed: 5005,
            layers: 1024,
            iters: 288,
            restarts: 10,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 1,
            cache_key_raw: false,
        };
        note(
            &mut all,
            b.run("shard/plan 1024 layers x 16 shards", 16, || {
                shard::plan(&spec, 16).map(|m| m.len()).unwrap_or(0)
            }),
        );
        let fp = spec.fingerprint();
        let rec = shard::LayerRecord {
            job: 3,
            name: "layer4".into(),
            n: 8,
            d: 100,
            k: 3,
            algo: "nBOCS".into(),
            solver: "sa".into(),
            evals: 1176,
            best_y: 0.031_257_194_7,
            best_x: vec![1, -1].repeat(12),
            err: 0.0417,
            ratio: 0.158_203_125,
            cache_hits: 40,
            cache_misses: 1136,
            surrogate_failures: 0,
            fallback_proposals: 0,
            rejected_costs: 0,
        };
        note(
            &mut all,
            b.run("shard/record jsonl roundtrip x64", 64, || {
                let mut evals = 0usize;
                for _ in 0..64 {
                    let line =
                        rec.to_json_line(&fp).expect("finite record");
                    evals += shard::LayerRecord::parse_line(&line, &fp)
                        .expect("roundtrip")
                        .evals;
                }
                evals
            }),
        );
    }

    let path = bench::default_json_path(&label);
    bench::write_json(&path, &label, quick, &all)
        .expect("write BENCH json");
    println!("\nwrote {} ({} rows)", path.display(), all.len());
}
