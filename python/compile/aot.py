"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once by ``make artifacts``; the rust coordinator loads the text via
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.
HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
links) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Emits into ``--out`` (default ../artifacts):

  cost_batch.hlo.txt    (W[n,d], M[b,n,k])                  -> (cost[b],)
  gram.hlo.txt          (Phi[nmax,p], y[nmax,1])            -> (G, gv, yy)
  bocs_sample.hlo.txt   (G[p,p], gv[p,1], lam[p], s2, z[p]) -> (alpha, hld)
  fm_epoch_k{8,12}.hlo.txt (X, y, mask, w0, w, V, lr)       -> (w0, w, V)
  meta.json             shape/layout contract consumed by rust runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def lower_all(n, d, k, batch, nmax, kfms):
    """Lower every graph at the configured paper-scale shapes."""
    nbits = n * k
    p = 1 + nbits + nbits * (nbits - 1) // 2
    arts = {}

    arts["cost_batch"] = jax.jit(model.cost_batch_graph).lower(
        _spec(n, d), _spec(batch, n, k)
    )
    arts["gram"] = jax.jit(model.gram_graph).lower(
        _spec(nmax, p), _spec(nmax, 1)
    )
    arts["bocs_sample"] = jax.jit(model.bocs_sample_graph).lower(
        _spec(p, p), _spec(p, 1), _spec(p), _spec(), _spec(p)
    )
    for kfm in kfms:
        arts[f"fm_epoch_k{kfm}"] = jax.jit(model.fm_epoch_graph).lower(
            _spec(nmax, nbits),  # X
            _spec(nmax),  # y
            _spec(nmax),  # mask
            _spec(1),  # w0
            _spec(nbits),  # w
            _spec(nbits, kfm),  # V
            _spec(1),  # lr
        )

    meta = {
        "n": n,
        "d": d,
        "k": k,
        "nbits": nbits,
        "p": p,
        "batch": batch,
        "nmax": nmax,
        "kfms": list(kfms),
        "fm_steps": model.FM_STEPS,
        "feature_order": "bias, linear, upper-tri pairs (lexicographic)",
    }
    return arts, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--n", type=int, default=8, help="rows of W")
    ap.add_argument("--d", type=int, default=100, help="cols of W")
    ap.add_argument("--k", type=int, default=3, help="decomposition rank")
    ap.add_argument("--batch", type=int, default=256, help="cost batch B")
    ap.add_argument(
        "--nmax", type=int, default=1280, help="padded dataset rows"
    )
    ap.add_argument("--kfm", type=int, nargs="*", default=[8, 12])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    arts, meta = lower_all(
        args.n, args.d, args.k, args.batch, args.nmax, args.kfm
    )
    for name, lowered in arts.items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {os.path.join(args.out, 'meta.json')}")


if __name__ == "__main__":
    main()
