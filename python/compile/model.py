"""L2 JAX graphs — the compute surface the rust coordinator calls via PJRT.

The "model" of this paper is not a neural network but the black-box cost of
the NLIP formulation plus the two dense-linear-algebra routines the BBO loop
leans on.  Four graphs are AOT-lowered (see ``aot.py``):

  * ``cost_batch_graph``   — batched residual cost (wraps the L1 Pallas
                             cost kernel; paper Eq. 8-9).
  * ``gram_graph``         — (Phi^T Phi, Phi^T y, y^T y) over the padded
                             dataset (wraps the L1 Pallas Gram kernel).
  * ``bocs_sample_graph``  — one Thompson draw from the Bayesian linear
                             regression posterior given the Gram moments:
                             the "fast Gaussian sampler" of the paper
                             (Rue 2001 / Bhattacharya 2016 route).
  * ``fm_epoch_graph``     — ``FM_STEPS`` full-batch Adam steps on a degree-2
                             factorisation machine (FMQA surrogate).

Feature convention shared with rust (``surrogate::features``):
``phi(x) = [1, x_1..x_n, x_1 x_2, x_1 x_3, .., x_{n-1} x_n]`` — bias first,
then linear terms, then upper-triangular pair products in lexicographic
order; P = 1 + n + n(n-1)/2.

All graphs take *fixed* shapes (padded datasets, zero rows inert) so that a
single HLO artifact serves the whole growing-dataset BBO run.
"""

import jax
import jax.numpy as jnp

from .kernels.cost_kernel import cost_batch
from .kernels.gram_kernel import gram

__all__ = [
    "cost_batch_graph",
    "gram_graph",
    "bocs_sample_graph",
    "fm_epoch_graph",
    "fm_predict",
    "FM_STEPS",
]

# Full-batch Adam steps per fm_epoch_graph call.  The rust FMQA driver calls
# the artifact a handful of times per BBO iteration (warm-started), matching
# the paper's retrain-each-iteration protocol.
FM_STEPS = 100
_ADAM_B1 = 0.9
_ADAM_B2 = 0.999
_ADAM_EPS = 1e-8
_FM_L2 = 1e-6


def cost_batch_graph(w, m_batch):
    """Batched black-box cost; returns a 1-tuple for the AOT boundary."""
    from .kernels.cost_kernel import DEFAULT_BLOCK_B

    b = m_batch.shape[0]
    block = DEFAULT_BLOCK_B if b % DEFAULT_BLOCK_B == 0 else b
    return (cost_batch(w, m_batch, block_b=block),)


def gram_graph(phi, y):
    """Gram moments of the (padded) dataset."""
    from .kernels.gram_kernel import DEFAULT_BLOCK_R

    n = phi.shape[0]
    block = DEFAULT_BLOCK_R if n % DEFAULT_BLOCK_R == 0 else n
    g, gv, yy = gram(phi, y, block_r=block)
    return g, gv, yy


def cholesky_hlo(a):
    """Left-looking Cholesky in plain HLO ops (fori_loop + masked algebra).

    ``jnp.linalg.cholesky`` lowers to a LAPACK custom-call with
    API_VERSION_TYPED_FFI on CPU, which the xla_extension 0.5.1 runtime
    behind the rust `xla` crate rejects — so the factorisation is written
    out manually.  O(P^3) as a loop of P rank-1-style column updates.
    """
    p = a.shape[0]
    idx = jnp.arange(p)

    def body(j, chol):
        row_j = jnp.where(idx < j, chol[j, :], 0.0)  # l[j, :j]
        d = a[j, j] - jnp.sum(row_j * row_j)
        ljj = jnp.sqrt(jnp.maximum(d, 1e-30))
        # col[i] = (a[i, j] - Σ_{k<j} l[i,k] l[j,k]) / l[j,j] for i > j.
        prods = chol @ row_j
        col = (a[:, j] - prods) / ljj
        col = jnp.where(idx > j, col, 0.0)
        col = col.at[j].set(ljj)
        return chol.at[:, j].set(col)

    return jax.lax.fori_loop(0, p, body, jnp.zeros_like(a))


def solve_lower_hlo(chol, b):
    """Forward substitution L y = b without LAPACK custom-calls."""
    p = chol.shape[0]
    idx = jnp.arange(p)

    def body(i, y):
        row = jnp.where(idx < i, chol[i, :], 0.0)
        yi = (b[i] - jnp.sum(row * y)) / chol[i, i]
        return y.at[i].set(yi)

    return jax.lax.fori_loop(0, p, body, jnp.zeros_like(b))


def solve_lower_t_hlo(chol, b):
    """Backward substitution L^T x = b without LAPACK custom-calls."""
    p = chol.shape[0]
    idx = jnp.arange(p)

    def body(step, x):
        i = p - 1 - step
        col = jnp.where(idx > i, chol[:, i], 0.0)
        xi = (b[i] - jnp.sum(col * x)) / chol[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, p, body, jnp.zeros_like(b))


def bocs_sample_graph(g, gv, lam, sigma2, z):
    """One posterior draw alpha ~ N(A^-1 b, A^-1) via Cholesky.

    A = G / sigma2 + diag(lam),  b = gv / sigma2, where G = Phi^T Phi and
    gv = Phi^T y come from ``gram_graph``; ``lam`` is the per-coefficient
    prior precision (this is what distinguishes the normal / normal-gamma /
    horseshoe BOCS variants — the rust Gibbs samplers feed different lam),
    ``z`` a standard-normal vector supplied by the rust RNG so the artifact
    stays deterministic.

    Returns (alpha, logdet_term) where the second output is
    sum(log(diag(L))) — the half log-determinant of A, needed by the
    normal-gamma marginal update on the rust side.
    """
    a = g / sigma2 + jnp.diag(lam)
    chol = cholesky_hlo(a)
    b = gv[:, 0] / sigma2
    # mu = A^-1 b through the factor; sample = mu + L^-T z.
    t = solve_lower_hlo(chol, b)
    mu = solve_lower_t_hlo(chol, t)
    u = solve_lower_t_hlo(chol, z)
    half_logdet = jnp.sum(jnp.log(jnp.diagonal(chol))).reshape(1)
    return mu + u, half_logdet


def fm_predict(x, w0, w, v):
    """Degree-2 factorisation machine forward pass (paper Eq. 11-12)."""
    xv = x @ v  # (N, k)
    x2v2 = (x * x) @ (v * v)
    pair = 0.5 * jnp.sum(xv * xv - x2v2, axis=1)
    return w0[0] + x @ w + pair


def _fm_loss(params, x, y, mask):
    w0, w, v = params
    pred = fm_predict(x, w0, w, v)
    n_eff = jnp.maximum(jnp.sum(mask), 1.0)
    mse = jnp.sum(mask * (pred - y) ** 2) / n_eff
    reg = _FM_L2 * (jnp.sum(w * w) + jnp.sum(v * v))
    return mse + reg


def fm_epoch_graph(x, y, mask, w0, w, v, lr):
    """``FM_STEPS`` full-batch Adam steps; returns updated (w0, w, V).

    Padding rows carry mask == 0 so they contribute neither loss nor
    gradient.  Adam moments are re-initialised per call; across calls the
    parameters themselves warm-start, which is the useful state.
    """
    grad_fn = jax.grad(_fm_loss)

    def step(i, state):
        params, m, vv = state
        grads = grad_fn(params, x, y, mask)
        t = (i + 1).astype(jnp.float32)
        bc1 = 1.0 - _ADAM_B1**t
        bc2 = 1.0 - _ADAM_B2**t

        def upd(p, g, mi, vi):
            mi = _ADAM_B1 * mi + (1.0 - _ADAM_B1) * g
            vi = _ADAM_B2 * vi + (1.0 - _ADAM_B2) * g * g
            p = p - lr[0] * (mi / bc1) / (jnp.sqrt(vi / bc2) + _ADAM_EPS)
            return p, mi, vi

        out = [upd(p, g, mi, vi) for p, g, mi, vi in zip(params, grads, m, vv)]
        params = tuple(o[0] for o in out)
        m = tuple(o[1] for o in out)
        vv = tuple(o[2] for o in out)
        return params, m, vv

    zeros = tuple(jnp.zeros_like(p) for p in (w0, w, v))
    params, _, _ = jax.lax.fori_loop(
        0, FM_STEPS, step, ((w0, w, v), zeros, zeros)
    )
    return params
