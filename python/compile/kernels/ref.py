"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel in this package is
checked against these reference implementations by pytest (allclose), and the
rust native implementations are cross-checked against the AOT artifacts that
embed the kernels — so ``ref.py`` anchors the whole stack.

Cost convention (paper Eq. 8-9): for a target matrix ``W`` (N x D) and a
binary matrix ``M`` (N x K, entries +-1),

    cost(W, M) = || W - M (M^T M)^+ M^T W ||_F^2

i.e. the squared Frobenius norm of the residual after projecting W onto the
column space of M (the real factor ``C = M^+ W`` is eliminated by least
squares).  Rank-deficient M (duplicate / collinear columns) is handled with
the pseudoinverse, exactly as ``numpy.linalg.pinv`` would.
"""

import jax
import jax.numpy as jnp

__all__ = ["cost_ref", "cost_batch_ref", "gram_ref", "lstsq_c_ref"]


def cost_ref(w, m):
    """Residual cost for a single candidate ``m`` — pseudoinverse form."""
    w = w.astype(jnp.float32)
    m = m.astype(jnp.float32)
    c = jnp.linalg.pinv(m, rtol=1e-5) @ w
    r = w - m @ c
    return jnp.sum(r * r)


def cost_batch_ref(w, m_batch):
    """Vectorised :func:`cost_ref` over a leading batch axis of M."""
    return jax.vmap(lambda m: cost_ref(w, m))(m_batch)


def lstsq_c_ref(w, m):
    """The eliminated real factor C = (M^T M)^+ M^T W (paper Eq. 6)."""
    return jnp.linalg.pinv(m.astype(jnp.float32), rtol=1e-5) @ w.astype(
        jnp.float32
    )


def gram_ref(phi, y):
    """Gram matrix and moment vector: (Phi^T Phi, Phi^T y, y^T y)."""
    phi = phi.astype(jnp.float32)
    y = y.astype(jnp.float32)
    return phi.T @ phi, phi.T @ y, jnp.sum(y * y)
