"""L1 Pallas kernel: row-tiled Gram accumulation for the BOCS surrogate.

Every BBO iteration rebuilds the Bayesian-linear-regression posterior from
the quadratic feature matrix Phi (rows = evaluated candidates, cols = the
1 + n + n(n-1)/2 quadratic features).  The O(N * P^2) Gram product
``Phi^T Phi`` dominates that rebuild, and — unlike the Gibbs sweeps that
reuse it — is a classic MXU tiling problem, so it lives in a kernel.

Blocking: the grid walks row-blocks of Phi; each step loads a
(BLOCK_R, P) slab into VMEM, contracts it on the MXU, and accumulates into
the (P, P) output block, which maps to the same tile at every step (the
canonical Pallas accumulation pattern: initialise at program_id == 0, then
``+=``).  Padding rows are all-zero and therefore accumulate nothing, which
is how the fixed-shape AOT artifact supports a growing dataset.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram", "DEFAULT_BLOCK_R"]

DEFAULT_BLOCK_R = 128


def _gram_kernel(phi_ref, y_ref, g_ref, gv_ref, yy_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        gv_ref[...] = jnp.zeros_like(gv_ref)
        yy_ref[...] = jnp.zeros_like(yy_ref)

    blk = phi_ref[...]  # (R, P)
    yb = y_ref[...]  # (R, 1)
    g_ref[...] += blk.T @ blk
    gv_ref[...] += blk.T @ yb
    yy_ref[...] += jnp.sum(yb * yb, keepdims=True).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("block_r",))
def gram(phi, y, *, block_r=DEFAULT_BLOCK_R):
    """Accumulate (Phi^T Phi, Phi^T y, y^T y) over row tiles of Phi.

    Args:
      phi: (N, P) float32 feature matrix; N must be a multiple of
        ``block_r``.  Zero rows are inert padding.
      y: (N, 1) float32 targets (zero on padding rows).

    Returns:
      (P, P) Gram matrix, (P, 1) moment vector, (1, 1) y^T y.
    """
    n, p = phi.shape
    if n % block_r != 0:
        raise ValueError(f"rows {n} not a multiple of block {block_r}")
    grid = (n // block_r,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, p), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((p, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT execution; Mosaic is TPU-only
    )(phi.astype(jnp.float32), y.astype(jnp.float32))
