"""L1 Pallas kernel: batched integer-decomposition residual cost.

This is the hot-spot of the whole system — the black-box function of the
paper's NLIP formulation, evaluated for a *batch* of candidate binary
matrices at once (BBO evaluations, data augmentation, and the brute-force
sweep all funnel through it).

TPU adaptation (DESIGN.md §2): the paper's reference implementation is plain
NumPy ``pinv``; a TPU kernel cannot call LAPACK, so the projection is
computed by an unrolled, *branch-free* modified Gram-Schmidt over the K
columns of each candidate, entirely with VPU-friendly elementwise /
small-contraction arithmetic:

    cost(W, M) = ||W||_F^2  -  sum_k || q_k^T W ||_2^2

where q_1..q_K is a (threshold-masked) orthonormal basis of col(M).
Rank-deficient candidates (duplicate / collinear columns) are handled
exactly: a column whose residual norm falls below ``eps`` is masked to zero
and simply contributes nothing — the same semantics as the pseudoinverse.
For integer M the Gram determinant is a non-negative integer, so residual
norms of independent columns are bounded below by 1/det >= 1/N^K; ``eps``
sits orders of magnitude under that floor but far above fp32 noise.

Blocking: the grid runs over the batch axis only. W (N x D, ~3.2 KB at the
paper scale) is resident in VMEM for every grid step via a constant
index_map; each step streams one (BLOCK_B, N, K) slab of candidates and
writes a (BLOCK_B,) cost vector, so VMEM footprint is
O(N*D + BLOCK_B*N*K) — a few hundred KB at BLOCK_B=256.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cost_batch", "DEFAULT_BLOCK_B", "EPS_RANK"]

DEFAULT_BLOCK_B = 256
# Rank threshold for the masked Gram-Schmidt (see module docstring).
EPS_RANK = 1e-3


def _cost_kernel(w_ref, m_ref, o_ref, *, k_cols, eps):
    """One grid step: costs for a (BLOCK_B, N, K) slab of candidates."""
    w = w_ref[...]  # (N, D) — resident across the whole grid
    m = m_ref[...]  # (B, N, K)
    w_tot = jnp.sum(w * w)

    basis = []  # orthonormalised columns, each (B, N)
    acc = jnp.zeros((m.shape[0],), jnp.float32)
    for k in range(k_cols):
        v = m[:, :, k]
        # Two MGS passes: the second re-orthogonalisation squashes the
        # fp32 error of the first when earlier columns nearly align.
        for _ in range(2):
            for q in basis:
                coeff = jnp.sum(q * v, axis=1, keepdims=True)
                v = v - coeff * q
        nrm2 = jnp.sum(v * v, axis=1, keepdims=True)
        keep = (nrm2 > eps).astype(jnp.float32)
        q = v * keep / jnp.sqrt(jnp.where(nrm2 > eps, nrm2, 1.0))
        basis.append(q)
        proj = jnp.einsum("bn,nd->bd", q, w)  # (B, D)
        acc = acc + jnp.sum(proj * proj, axis=1)

    o_ref[...] = w_tot - acc


@functools.partial(jax.jit, static_argnames=("block_b",))
def cost_batch(w, m_batch, *, block_b=DEFAULT_BLOCK_B):
    """Residual costs for a batch of candidate binary matrices.

    Args:
      w: (N, D) float32 target matrix.
      m_batch: (B, N, K) float32 candidates with entries in {-1, +1};
        B must be a multiple of ``block_b`` (the AOT artifact fixes
        B == block_b; callers pad and mask on the rust side).
      block_b: batch tile size per grid step.

    Returns:
      (B,) float32 costs ``||W - M M^+ W||_F^2``.
    """
    b, n, k = m_batch.shape
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block {block_b}")
    grid = (b // block_b,)
    kernel = functools.partial(_cost_kernel, k_cols=k, eps=EPS_RANK)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, w.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((block_b, n, k), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,  # CPU-PJRT execution; Mosaic is TPU-only
    )(w.astype(jnp.float32), m_batch.astype(jnp.float32))
