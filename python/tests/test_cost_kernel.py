"""Pallas cost kernel vs the pure-jnp pseudoinverse oracle.

The kernel must agree with ``ref.cost_ref`` on every shape/rank pattern the
coordinator can feed it: generic candidates, duplicate columns, sign-flipped
columns (rank deficiency), K == N (perfect reconstruction) and K == 1.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.cost_kernel import cost_batch
from compile.kernels.ref import cost_batch_ref, cost_ref, lstsq_c_ref

RNG = np.random.default_rng(0)


def _rand_w(n, d, rng):
    return rng.normal(size=(n, d)).astype(np.float32)


def _rand_m(b, n, k, rng):
    return rng.choice([-1.0, 1.0], size=(b, n, k)).astype(np.float32)


def _check(w, m_batch, rtol=2e-4, atol=2e-4):
    got = np.asarray(cost_batch(jnp.asarray(w), jnp.asarray(m_batch),
                                block_b=m_batch.shape[0]))
    want = np.asarray(cost_batch_ref(jnp.asarray(w), jnp.asarray(m_batch)))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


# ---------------------------------------------------------------- basic --


def test_paper_shape_batch():
    w = _rand_w(8, 100, RNG)
    m = _rand_m(64, 8, 3, RNG)
    _check(w, m)


def test_grid_multiple_blocks():
    w = _rand_w(8, 100, RNG)
    m = _rand_m(64, 8, 3, RNG)
    got = np.asarray(cost_batch(jnp.asarray(w), jnp.asarray(m), block_b=16))
    want = np.asarray(cost_batch_ref(jnp.asarray(w), jnp.asarray(m)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_batch_not_multiple_raises():
    w = _rand_w(4, 5, RNG)
    m = _rand_m(7, 4, 2, RNG)
    with pytest.raises(ValueError):
        cost_batch(jnp.asarray(w), jnp.asarray(m), block_b=4)


# ----------------------------------------------------- rank deficiency --


def test_duplicate_columns_match_pinv():
    w = _rand_w(8, 20, RNG)
    m = _rand_m(8, 8, 3, RNG)
    m[:, :, 2] = m[:, :, 0]  # exact duplicate -> rank 2
    _check(w, m)


def test_sign_flipped_column_match_pinv():
    w = _rand_w(8, 20, RNG)
    m = _rand_m(8, 8, 3, RNG)
    m[:, :, 1] = -m[:, :, 0]  # collinear -> rank 2
    _check(w, m)


def test_all_columns_identical():
    w = _rand_w(6, 10, RNG)
    m = np.ones((4, 6, 3), np.float32)
    m[2] = -1.0
    _check(w, m)


def test_rank_deficient_cost_equals_reduced_k():
    """Duplicating a column must give exactly the K-1 decomposition cost."""
    w = _rand_w(8, 30, RNG)
    m2 = _rand_m(1, 8, 2, RNG)
    m3 = np.concatenate([m2, m2[:, :, :1]], axis=2)
    c2 = float(cost_ref(jnp.asarray(w), jnp.asarray(m2[0])))
    c3 = np.asarray(cost_batch(jnp.asarray(w), jnp.asarray(m3), block_b=1))[0]
    np.testing.assert_allclose(c3, c2, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- structure --


def test_k_equals_n_perfect_reconstruction():
    """K == N with independent columns reconstructs W exactly (Eq. 2)."""
    w = _rand_w(4, 12, RNG)
    # Hadamard-like independent +-1 basis.
    h = np.array(
        [[1, 1, 1, 1], [1, -1, 1, -1], [1, 1, -1, -1], [1, -1, -1, 1]],
        np.float32,
    )
    got = np.asarray(
        cost_batch(jnp.asarray(w), jnp.asarray(h[None]), block_b=1)
    )
    np.testing.assert_allclose(got, [0.0], atol=1e-3)


def test_cost_nonnegative_and_bounded_by_w_norm():
    w = _rand_w(8, 40, RNG)
    m = _rand_m(32, 8, 3, RNG)
    got = np.asarray(cost_batch(jnp.asarray(w), jnp.asarray(m), block_b=32))
    wnorm = float(np.sum(w * w))
    assert np.all(got >= -1e-3)
    assert np.all(got <= wnorm + 1e-3)


def test_sign_and_permutation_invariance():
    """cost(M) is invariant under column sign flips and permutations."""
    w = _rand_w(8, 25, RNG)
    m = _rand_m(1, 8, 3, RNG)[0]
    variants = [
        m,
        m[:, [1, 0, 2]],
        m[:, [2, 1, 0]],
        m * np.array([-1, 1, 1], np.float32),
        m * np.array([-1, -1, 1], np.float32),
        (m * -1)[:, [2, 0, 1]],
    ]
    batch = np.stack(variants).astype(np.float32)
    got = np.asarray(
        cost_batch(jnp.asarray(w), jnp.asarray(batch), block_b=len(variants))
    )
    np.testing.assert_allclose(got, got[0] * np.ones_like(got), rtol=1e-4,
                               atol=1e-4)


def test_residual_identity_vs_explicit_c():
    """cost == ||W - M C||^2 with C from the oracle lstsq (Eq. 3 vs Eq. 8)."""
    w = _rand_w(8, 15, RNG)
    m = _rand_m(1, 8, 3, RNG)[0]
    c = np.asarray(lstsq_c_ref(jnp.asarray(w), jnp.asarray(m)))
    explicit = float(np.sum((w - m @ c) ** 2))
    got = np.asarray(
        cost_batch(jnp.asarray(w), jnp.asarray(m[None]), block_b=1)
    )[0]
    np.testing.assert_allclose(got, explicit, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- hypothesis --


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 10),
    d=st.integers(1, 24),
    k=st.integers(1, 4),
    b=st.integers(1, 6),
    seed=st.integers(0, 2**32 - 1),
    degenerate=st.booleans(),
)
def test_kernel_matches_oracle_shape_sweep(n, d, k, b, seed, degenerate):
    rng = np.random.default_rng(seed)
    w = _rand_w(n, d, rng)
    m = _rand_m(b, n, k, rng)
    if degenerate and k >= 2:
        m[:, :, -1] = m[:, :, 0] * (-1.0 if seed % 2 else 1.0)
    _check(w, m, rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 8),
    k=st.integers(1, 3),
    scale=st.floats(1e-2, 1e2),
    seed=st.integers(0, 2**32 - 1),
)
def test_cost_scales_quadratically_with_w(n, k, scale, seed):
    """cost(s*W, M) == s^2 cost(W, M) — the projector is scale-free."""
    rng = np.random.default_rng(seed)
    w = _rand_w(n, 12, rng)
    m = _rand_m(2, n, k, rng)
    base = np.asarray(cost_batch(jnp.asarray(w), jnp.asarray(m), block_b=2))
    scaled = np.asarray(
        cost_batch(jnp.asarray(w * scale), jnp.asarray(m), block_b=2)
    )
    # atol tracks the fp32 cancellation floor of ||sW||^2 - captured.
    np.testing.assert_allclose(
        scaled, base * scale**2, rtol=3e-3,
        atol=1e-5 * max(1.0, scale**2),
    )
