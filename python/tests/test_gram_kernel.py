"""Pallas Gram kernel vs the dense jnp oracle, incl. padding semantics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.gram_kernel import gram
from compile.kernels.ref import gram_ref

RNG = np.random.default_rng(1)


def _check(phi, y, block_r):
    g, gv, yy = gram(jnp.asarray(phi), jnp.asarray(y), block_r=block_r)
    rg, rgv, ryy = gram_ref(jnp.asarray(phi), jnp.asarray(y[:, 0]))
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(gv)[:, 0], np.asarray(rgv), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        float(np.asarray(yy)[0, 0]), float(ryy), rtol=1e-4, atol=1e-4
    )


def test_paper_scale_single_block():
    phi = RNG.normal(size=(128, 61)).astype(np.float32)
    y = RNG.normal(size=(128, 1)).astype(np.float32)
    _check(phi, y, 128)


def test_multi_block_accumulation():
    phi = RNG.normal(size=(256, 31)).astype(np.float32)
    y = RNG.normal(size=(256, 1)).astype(np.float32)
    _check(phi, y, 32)


def test_zero_padding_rows_are_inert():
    phi = RNG.normal(size=(64, 13)).astype(np.float32)
    y = RNG.normal(size=(64, 1)).astype(np.float32)
    phi[40:] = 0.0
    y[40:] = 0.0
    g, gv, yy = gram(jnp.asarray(phi), jnp.asarray(y), block_r=16)
    rg, rgv, ryy = gram_ref(jnp.asarray(phi[:40]), jnp.asarray(y[:40, 0]))
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(gv)[:, 0], np.asarray(rgv), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        float(np.asarray(yy)[0, 0]), float(ryy), rtol=1e-4, atol=1e-4
    )


def test_rows_not_multiple_raises():
    phi = np.zeros((30, 4), np.float32)
    y = np.zeros((30, 1), np.float32)
    with pytest.raises(ValueError):
        gram(jnp.asarray(phi), jnp.asarray(y), block_r=16)


def test_gram_is_symmetric_psd():
    phi = RNG.normal(size=(64, 9)).astype(np.float32)
    y = np.zeros((64, 1), np.float32)
    g, _, _ = gram(jnp.asarray(phi), jnp.asarray(y), block_r=16)
    g = np.asarray(g)
    np.testing.assert_allclose(g, g.T, atol=1e-5)
    eig = np.linalg.eigvalsh(g.astype(np.float64))
    assert eig.min() >= -1e-3


@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([16, 32, 64, 128]),
    p=st.integers(1, 40),
    block=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**32 - 1),
)
def test_gram_matches_oracle_sweep(rows, p, block, seed):
    rng = np.random.default_rng(seed)
    phi = rng.normal(size=(rows, p)).astype(np.float32)
    y = rng.normal(size=(rows, 1)).astype(np.float32)
    _check(phi, y, block)
