"""L2 graph correctness: BOCS posterior sampler and FM trainer."""

import numpy as np

import jax.numpy as jnp

import jax

from compile.model import (
    cost_batch_graph,
    fm_epoch_graph,
    fm_predict,
)
from compile.model import bocs_sample_graph as _bocs_sample_graph

# The graphs are jitted by aot.py for the artifacts; jit here too so the
# fori_loop-based Cholesky doesn't re-trace per call.
bocs_sample_graph = jax.jit(_bocs_sample_graph)
from compile.kernels.ref import cost_batch_ref

RNG = np.random.default_rng(2)


def _posterior(phi, y, lam, sigma2):
    """Dense float64 reference posterior (mean, covariance)."""
    a = phi.T @ phi / sigma2 + np.diag(lam)
    cov = np.linalg.inv(a)
    mu = cov @ (phi.T @ y / sigma2)
    return mu, cov, a


def test_bocs_sample_zero_z_is_posterior_mean():
    p, rows = 7, 40
    phi = RNG.normal(size=(rows, p)).astype(np.float32)
    y = RNG.normal(size=rows).astype(np.float32)
    lam = np.full(p, 0.5, np.float32)
    sigma2 = 0.3
    mu, _, _ = _posterior(phi.astype(np.float64), y.astype(np.float64),
                          lam.astype(np.float64), sigma2)
    g = phi.T @ phi
    gv = (phi.T @ y)[:, None]
    alpha, _ = bocs_sample_graph(
        jnp.asarray(g), jnp.asarray(gv), jnp.asarray(lam),
        jnp.float32(sigma2), jnp.zeros(p, jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(alpha), mu, rtol=1e-3, atol=1e-3)


def test_bocs_sample_half_logdet():
    p, rows = 5, 30
    phi = RNG.normal(size=(rows, p)).astype(np.float32)
    y = RNG.normal(size=rows).astype(np.float32)
    lam = np.full(p, 2.0, np.float32)
    sigma2 = 1.0
    _, _, a = _posterior(phi.astype(np.float64), y.astype(np.float64),
                         lam.astype(np.float64), sigma2)
    g = phi.T @ phi
    gv = (phi.T @ y)[:, None]
    _, hld = bocs_sample_graph(
        jnp.asarray(g), jnp.asarray(gv), jnp.asarray(lam),
        jnp.float32(sigma2), jnp.zeros(p, jnp.float32)
    )
    want = 0.5 * np.linalg.slogdet(a)[1]
    np.testing.assert_allclose(float(np.asarray(hld)[0]), want, rtol=1e-3)


def test_bocs_sample_moments():
    """Empirical mean/cov over many z-draws match the analytic posterior."""
    p, rows, draws = 4, 50, 4000
    phi = RNG.normal(size=(rows, p)).astype(np.float32)
    y = RNG.normal(size=rows).astype(np.float32)
    lam = np.full(p, 1.0, np.float32)
    sigma2 = 0.5
    mu, cov, _ = _posterior(phi.astype(np.float64), y.astype(np.float64),
                            lam.astype(np.float64), sigma2)
    g = jnp.asarray(phi.T @ phi)
    gv = jnp.asarray((phi.T @ y)[:, None])
    zs = RNG.normal(size=(draws, p)).astype(np.float32)
    samples = np.stack([
        np.asarray(bocs_sample_graph(g, gv, jnp.asarray(lam),
                                     jnp.float32(sigma2),
                                     jnp.asarray(z))[0])
        for z in zs
    ])
    emp_mu = samples.mean(axis=0)
    emp_cov = np.cov(samples.T)
    np.testing.assert_allclose(
        emp_mu, mu,
        atol=float(4.0 * np.sqrt(np.diag(cov).max() / draws) + 1e-3),
    )
    np.testing.assert_allclose(emp_cov, cov, atol=0.15 * np.abs(cov).max()
                               + 1e-3)


def test_fm_predict_matches_bruteforce_pairs():
    n, k, rows = 6, 3, 10
    x = RNG.choice([-1.0, 1.0], size=(rows, n)).astype(np.float32)
    w0 = RNG.normal(size=1).astype(np.float32)
    w = RNG.normal(size=n).astype(np.float32)
    v = RNG.normal(size=(n, k)).astype(np.float32)
    pred = np.asarray(fm_predict(jnp.asarray(x), jnp.asarray(w0),
                                 jnp.asarray(w), jnp.asarray(v)))
    # brute-force Eq. 11
    want = np.empty(rows)
    for r in range(rows):
        s = w0[0] + x[r] @ w
        for i in range(n):
            for j in range(i + 1, n):
                s += (v[i] @ v[j]) * x[r, i] * x[r, j]
        want[r] = s
    np.testing.assert_allclose(pred, want, rtol=1e-4, atol=1e-4)


def test_fm_epoch_reduces_loss_and_ignores_padding():
    n, kfm, rows, pad = 8, 4, 48, 16
    x = RNG.choice([-1.0, 1.0], size=(rows + pad, n)).astype(np.float32)
    # Planted FM model as ground truth.
    v_true = RNG.normal(size=(n, 2)).astype(np.float32)
    y = np.asarray(fm_predict(jnp.asarray(x), jnp.zeros(1, np.float32),
                              jnp.zeros(n, np.float32), jnp.asarray(v_true)))
    y = y.astype(np.float32)
    mask = np.ones(rows + pad, np.float32)
    mask[rows:] = 0.0
    # Poison the padding rows: they must not influence training.
    x[rows:] = 37.0
    y[rows:] = -1e6

    w0 = np.zeros(1, np.float32)
    w = np.zeros(n, np.float32)
    v = (0.01 * RNG.normal(size=(n, kfm))).astype(np.float32)

    def loss(w0_, w_, v_):
        pred = np.asarray(fm_predict(jnp.asarray(x), jnp.asarray(w0_),
                                     jnp.asarray(w_), jnp.asarray(v_)))
        return float(np.mean((pred[:rows] - y[:rows]) ** 2))

    before = loss(w0, w, v)
    for _ in range(6):
        w0_j, w_j, v_j = fm_epoch_graph(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask),
            jnp.asarray(w0), jnp.asarray(w), jnp.asarray(v),
            jnp.asarray(np.array([0.05], np.float32)),
        )
        w0, w, v = (np.asarray(w0_j), np.asarray(w_j), np.asarray(v_j))
    after = loss(w0, w, v)
    assert np.isfinite(after)
    assert after < 0.2 * before


def test_cost_batch_graph_wraps_kernel():
    w = RNG.normal(size=(8, 100)).astype(np.float32)
    m = RNG.choice([-1.0, 1.0], size=(256, 8, 3)).astype(np.float32)
    (got,) = cost_batch_graph(jnp.asarray(w), jnp.asarray(m))
    want = np.asarray(cost_batch_ref(jnp.asarray(w), jnp.asarray(m)))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
