"""AOT emitter: artifacts exist, are valid HLO text, meta is consistent."""

import json
import os

from compile.aot import lower_all, to_hlo_text


def test_lower_all_tiny(tmp_path):
    arts, meta = lower_all(n=4, d=6, k=2, batch=8, nmax=32, kfms=[3])
    assert set(arts) == {"cost_batch", "gram", "bocs_sample", "fm_epoch_k3"}
    assert meta["nbits"] == 8
    assert meta["p"] == 1 + 8 + 8 * 7 // 2
    for name, lowered in arts.items():
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        # Entry computation present with a tuple root (return_tuple=True).
        assert "ENTRY" in text, name
        # The rust-side runtime (xla_extension 0.5.1) rejects TYPED_FFI
        # custom-calls; no artifact may contain ANY custom-call (this is
        # why bocs_sample hand-rolls its Cholesky).
        assert "custom-call" not in text, f"{name} would not load in rust"
        path = tmp_path / f"{name}.hlo.txt"
        path.write_text(text)
        assert path.stat().st_size > 100
    (tmp_path / "meta.json").write_text(json.dumps(meta))
    reread = json.loads((tmp_path / "meta.json").read_text())
    assert reread == meta


def test_paper_scale_meta_contract():
    # Shape contract the rust runtime hard-depends on (P = 301 for n = 24).
    _, meta = lower_all(n=8, d=100, k=3, batch=256, nmax=1280, kfms=[8, 12])
    assert meta["nbits"] == 24
    assert meta["p"] == 301
    assert meta["batch"] == 256
    assert meta["nmax"] == 1280
    assert meta["kfms"] == [8, 12]
