"""Hand-rolled HLO-friendly Cholesky / triangular solves vs LAPACK.

These exist because the rust-side XLA runtime (xla_extension 0.5.1)
rejects API_VERSION_TYPED_FFI custom-calls, so the artifacts cannot use
``jnp.linalg.*``.  The manual versions must agree with LAPACK tightly.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.model import (
    cholesky_hlo as _cholesky_hlo,
    solve_lower_hlo as _solve_lower_hlo,
    solve_lower_t_hlo as _solve_lower_t_hlo,
)

# jit so the fori_loop bodies compile once per shape instead of
# re-tracing eagerly on every call.
cholesky_hlo = jax.jit(_cholesky_hlo)
solve_lower_hlo = jax.jit(_solve_lower_hlo)
solve_lower_t_hlo = jax.jit(_solve_lower_t_hlo)

RNG = np.random.default_rng(7)


def _spd(p, rng):
    a = rng.normal(size=(p + 3, p)).astype(np.float32)
    return (a.T @ a + 0.5 * np.eye(p)).astype(np.float32)


def test_cholesky_matches_lapack():
    a = _spd(20, RNG)
    got = np.asarray(cholesky_hlo(jnp.asarray(a)))
    want = np.linalg.cholesky(a.astype(np.float64))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_solves_roundtrip():
    p = 15
    a = _spd(p, RNG)
    x_true = RNG.normal(size=p).astype(np.float32)
    chol = cholesky_hlo(jnp.asarray(a))
    b = np.asarray(chol) @ x_true
    y = np.asarray(solve_lower_hlo(chol, jnp.asarray(b)))
    np.testing.assert_allclose(y, x_true, rtol=2e-3, atol=2e-3)
    bt = np.asarray(chol).T @ x_true
    xt = np.asarray(solve_lower_t_hlo(chol, jnp.asarray(bt)))
    np.testing.assert_allclose(xt, x_true, rtol=2e-3, atol=2e-3)


def test_full_posterior_solve_matches_dense():
    # A x = b through the two substitutions equals np.linalg.solve.
    p = 12
    a = _spd(p, RNG)
    b = RNG.normal(size=p).astype(np.float32)
    chol = cholesky_hlo(jnp.asarray(a))
    x = np.asarray(
        solve_lower_t_hlo(chol, solve_lower_hlo(chol, jnp.asarray(b)))
    )
    want = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=5e-3, atol=5e-3)


def test_no_custom_calls_in_lowered_hlo():
    # The whole point: the lowered module must be custom-call-free.
    from compile.aot import to_hlo_text
    from compile.model import bocs_sample_graph

    p = 9
    spec = jax.ShapeDtypeStruct((p, p), jnp.float32)
    lowered = jax.jit(bocs_sample_graph).lower(
        spec,
        jax.ShapeDtypeStruct((p, 1), jnp.float32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((p,), jnp.float32),
    )
    text = to_hlo_text(lowered)
    assert "custom-call" not in text, "artifact would not load in rust"


@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 24), seed=st.integers(0, 2**31 - 1))
def test_cholesky_sweep(p, seed):
    rng = np.random.default_rng(seed)
    a = _spd(p, rng)
    got = np.asarray(cholesky_hlo(jnp.asarray(a)))
    want = np.linalg.cholesky(a.astype(np.float64))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
